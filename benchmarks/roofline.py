"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape x mesh) cell, in seconds per step:

  compute    = FLOPs_per_device / 197e12            (v5e bf16 peak)
  memory     = HBM_bytes_per_device / 819e9         (v5e HBM bw)
  collective = collective_bytes_per_device / 50e9   (per-link ICI, conservative)

Collective bytes come from the dry-run's loop-aware HLO walk (per-device
shapes). XLA's cost_analysis does not multiply FLOPs by loop trip counts
(every scan — layers, microbatches, flash chunks — is counted once), so
compute/memory use an ANALYTIC model derived from the configs; the raw
cost_analysis value is reported alongside for reference. The analytic model:

  train   : 6*N_active*tokens  (fwd+bwd weight flops)
            * (4/3 remat factor for policy "dots", 2x for "none")
            + attention 2*S^2*L_attn*H*hd*B  * 3(fwd+bwd) * 0.5(causal)
            + SSD ~= L_ssm*B*S*(Q*nh*hp + 2*nh*ds*hp + nh*ds*Q)
  prefill : 1/3 of the train weight flops (fwd only), attention x1
  decode  : 2*N_active*B + attention 2*B*S_cache*H*hd*L_attn (one token)

  HBM traffic (per device):
  train   : params read 3x (fwd, bwd-dgrad, bwd-wgrad) * microbatches
            + grads + opt-state rw + 2x activation stash
  prefill : params read + KV cache write + 2x activations
  decode  : params read + full KV cache read (the defining decode cost)

MODEL_FLOPS := 6*N_active*D (train) / 2*N_active*D (inference) — the
"useful flops" numerator for the efficiency ratio.
"""
from __future__ import annotations

import glob
import json
import os
from dataclasses import dataclass

from repro.configs.base import ARCHS, SHAPES

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def _counts(cfg):
    L = cfg.n_layers
    per = len(cfg.pattern)
    n_attn = sum(1 for k in cfg.pattern if k.startswith("attn")) * (L // per)
    n_local = sum(1 for k in cfg.pattern if k == "attn_l") * (L // per)
    n_ssm = sum(1 for k in cfg.pattern if k == "mamba") * (L // per)
    return n_attn, n_local, n_ssm


def analytic_flops(arch: str, shape: str, n_dev: int) -> dict:
    cfg = ARCHS[arch]
    S, B, kind = SHAPES[shape]
    n_attn, n_local, n_ssm = _counts(cfg)
    N_act = cfg.active_param_count()
    H, hd = cfg.n_heads, cfg.head_dim

    if kind == "train":
        tokens = B * S
        weight = 6 * N_act * tokens
        remat = 4 / 3 if cfg.remat_policy == "dots" else 2.0
        weight *= remat
        # causal attention, fwd+bwd (3x fwd cost)
        full_attn = (n_attn - n_local) * 2 * 2 * B * S * S * H * hd * 0.5 * 3
        local_attn = n_local * 2 * 2 * B * S * (2 * cfg.sliding_window) * H * hd * 0.5 * 3
        ssd = 0
        if n_ssm:
            mc = cfg.mamba_cfg()
            Q = mc.chunk
            ssd = n_ssm * B * S * (
                2 * Q * mc.n_heads * mc.head_dim          # intra-chunk QQ term
                + 4 * mc.n_heads * mc.d_state * mc.head_dim  # states in/out
            ) * 3
        model_flops = 6 * cfg.active_param_count() * tokens
    elif kind == "prefill":
        tokens = B * S
        weight = 2 * N_act * tokens
        full_attn = (n_attn - n_local) * 2 * 2 * B * S * S * H * hd * 0.5
        local_attn = n_local * 2 * 2 * B * S * (2 * cfg.sliding_window) * H * hd * 0.5
        ssd = 0
        if n_ssm:
            mc = cfg.mamba_cfg()
            ssd = n_ssm * B * S * (
                2 * mc.chunk * mc.n_heads * mc.head_dim
                + 4 * mc.n_heads * mc.d_state * mc.head_dim
            )
        model_flops = 2 * cfg.active_param_count() * tokens
    else:  # decode: one token, cache length S
        weight = 2 * N_act * B
        kv_len = min(S, cfg.sliding_window) if False else S
        full_attn = (n_attn - n_local) * 2 * 2 * B * S * cfg.n_kv_heads * hd
        local_attn = n_local * 2 * 2 * B * min(S, cfg.sliding_window or S) * cfg.n_kv_heads * hd
        ssd = 0
        if n_ssm:
            mc = cfg.mamba_cfg()
            ssd = n_ssm * B * 4 * mc.n_heads * mc.d_state * mc.head_dim
        model_flops = 2 * cfg.active_param_count() * B

    total = weight + full_attn + local_attn + ssd
    return {
        "total_per_dev": total / n_dev,
        "model_flops": model_flops,
        "useful_ratio": model_flops / max(total, 1),
        "attn_share": (full_attn + local_attn) / max(total, 1),
    }


def analytic_hbm_bytes(arch: str, shape: str, n_dev: int, rec: dict) -> float:
    cfg = ARCHS[arch]
    S, B, kind = SHAPES[shape]
    P_bytes = cfg.param_count() * 2 / n_dev  # bf16, fully sharded
    n_attn, n_local, n_ssm = _counts(cfg)
    kv_per_layer = 2 * cfg.n_kv_heads * cfg.head_dim * 2  # bytes/token
    if kind == "train":
        mb = 4  # dry-run default microbatching
        traffic = 3 * P_bytes * mb              # weights streamed per microbatch
        traffic += 3 * P_bytes                  # grads + m/v read-write (approx)
        act = rec["memory"].get("temp_bytes_per_device") or 0
        traffic += 2 * act
    elif kind == "prefill":
        traffic = P_bytes
        traffic += B * S * (n_attn * kv_per_layer) / n_dev  # KV write
        traffic += 2 * (rec["memory"].get("temp_bytes_per_device") or 0)
    else:
        kv_full = B * S * ((n_attn - n_local) * kv_per_layer)
        kv_local = B * min(S, cfg.sliding_window or S) * (n_local * kv_per_layer)
        ssm_state = 0
        if n_ssm:
            mc = cfg.mamba_cfg()
            ssm_state = B * n_ssm * mc.n_heads * mc.d_state * mc.head_dim * 4
        traffic = P_bytes + (kv_full + kv_local + ssm_state) / n_dev
    return traffic


@dataclass
class Cell:
    arch: str
    shape: str
    mesh: str
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    useful_ratio: float
    peak_gib: float
    cost_flops_raw: float
    recommendation: str


def analyse(artifact_dir="artifacts/dryrun"):
    cells = []
    for path in sorted(glob.glob(os.path.join(artifact_dir, "*.json"))):
        rec = json.load(open(path))
        arch, shape, mesh = rec["arch"], rec["shape"], rec["mesh"]
        n_dev = rec["n_devices"]
        fl = analytic_flops(arch, shape, n_dev)
        compute_s = fl["total_per_dev"] / PEAK_FLOPS
        memory_s = analytic_hbm_bytes(arch, shape, n_dev, rec) / HBM_BW
        collective_s = rec["collectives"]["total_bytes"] / ICI_BW
        terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
        dominant = max(terms, key=terms.get)
        rec_msg = {
            "compute": "compute-bound: raise arithmetic intensity is moot — this is the roofline target; shave remat/useful-ratio waste",
            "memory": "memory-bound: cut bytes (weight streaming per microbatch, activation stash, KV dtype)",
            "collective": "collective-bound: cut wire bytes (sequence-parallel resharding, fewer FSDP regathers, int8 grads, lower MoE capacity)",
        }[dominant]
        cells.append(Cell(
            arch=arch, shape=shape, mesh=mesh,
            compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
            dominant=dominant, useful_ratio=fl["useful_ratio"],
            peak_gib=(rec["memory"]["peak_bytes_per_device"] or 0) / 2**30,
            cost_flops_raw=rec["cost"].get("flops", float("nan")),
            recommendation=rec_msg,
        ))
    return cells


def table(cells, mesh="pod"):
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant | "
           "useful | peak GiB/dev |")
    sep = "|" + "---|" * 8
    lines = [hdr, sep]
    for c in cells:
        if c.mesh != mesh:
            continue
        lines.append(
            f"| {c.arch} | {c.shape} | {c.compute_s:.3e} | {c.memory_s:.3e} | "
            f"{c.collective_s:.3e} | **{c.dominant}** | {c.useful_ratio:.2f} | "
            f"{c.peak_gib:.1f} |"
        )
    return "\n".join(lines)


def main():
    cells = analyse()
    print(table(cells, "pod"))
    print()
    counts = {}
    for c in cells:
        if c.mesh == "pod":
            counts[c.dominant] = counts.get(c.dominant, 0) + 1
    print("dominant-term histogram (single pod):", counts)


if __name__ == "__main__":
    main()
