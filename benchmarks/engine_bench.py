"""Engine benchmark: reproduce the paper's crossover curve, tuned vs default.

Also benchmarks the serving front door: steady-state throughput of the
async micro-batching queue (``AsyncSortService`` — individual requests
coalesced across producers) against the hand-batched sync path
(``SortService.submit`` with a caller-assembled batch).  The delta between
those two rows is the cost of letting the queue do the batching for you.
The ``moe_dispatch_adaptive`` row times the other consumer of the unified
exchange layer: MoE expert dispatch at a *learned* capacity factor, after a
skewed router paid its overflow retry exactly once (docs/exchange.md).

Sweeps data sizes over the four strategies (plus a Pallas-kernel local-sort
column, ``B_shared_pallas`` — interpret-mode numbers off-TPU, so read that
column as a correctness/plumbing check on CPU and a real contender on TPU)
on a forced multi-device host mesh, autotunes a plan per size bucket, and
reports what the tuned plan buys over the pre-engine default rule ("cluster
if mesh else shared_hybrid").  The paper's finding this automates: the shared
hybrid wins small sizes, the cluster MSD-radix model wins large ones — where
the crossover sits depends on the machine, which is exactly why it's
measured, not hard-coded.

The ``skew`` section sweeps adversarial key distributions (all-equal,
Zipfian, one-hot, clustered) across the two partition families: radix rows
pay overflow retries with peak/mean bucket ratios far above 2, sample rows
hold ratio ~1 with zero retries at the same capacity — the skew story
tests/test_skew.py asserts, with wall-clock attached.

The off-default ``gloo`` section (``--sections gloo``) answers the question
the forced mesh cannot: what does the real wire cost?  It runs one timing
body twice through the multihost harness — 2 genuine ``jax.distributed``
processes exchanging over gloo vs the single-process forced 2-device mesh —
and reports the cluster strategy's ``wire_cost`` ratio plus the
cluster-vs-shared crossover under both topologies.

The ``frontend`` section benches the multi-tenant SLO front door
(``repro.engine.frontend``): warm-vs-cold wall-clock replay (what AOT
``warmup`` buys on first-request latency and SLO goodput) and two
deterministic ManualClock overload simulations (one saturated tenant; three
tenants with a Zipf-skewed rate split) reporting p50/p95/p99 + goodput.

Prints ``name,us_per_call,derived`` CSV rows (benchmark harness contract).
``--snapshot out.json`` also writes the rows machine-readably (schema in
docs/benchmarks.md) and ``--compare prev.json`` diffs against an earlier
snapshot, exiting nonzero when any shared row regresses beyond
``--threshold`` (time ratio) or loses more than 0.05 goodput.

  PYTHONPATH=src python benchmarks/engine_bench.py            # full sweep
  PYTHONPATH=src python benchmarks/engine_bench.py --smoke    # CI-sized
  PYTHONPATH=src python benchmarks/engine_bench.py --smoke \
      --sections frontend --snapshot BENCH_new.json --compare BENCH_PR6.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")
sys.path.insert(
    0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
)

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402


def serving_rows(rng, *, reps: int, smoke: bool):
    """Serving front door: hand-batched sync vs async micro-batching queue.

    Both paths run the identical executable (the queue shares the sync
    service's compiled cache); the async row pays the queue hop + coalescing
    window, and its ``derived`` column reports keys/s, batch fill, and the
    p50 queue latency so the overhead is visible, not vibes.
    """
    from repro.engine import AsyncSortService, SortService

    n_req = 16 if smoke else 64
    req_len = 1000 if smoke else 4000
    keys_total = n_req * req_len
    reqs = [rng.integers(0, 1_000_000, req_len).astype(np.int32)
            for _ in range(n_req)]
    rows = []

    svc = SortService()
    svc.submit(reqs)  # warmup: compiles the (n_req, bucket) executable
    # warm every pow2 batch-size bucket too: the queue's deadline flushes
    # produce partial batches, and a cold compile landing inside a timed
    # loop would swamp the ms-scale queue overhead these rows measure
    bb = 1
    while bb < n_req:
        svc.submit(reqs[:bb])
        bb *= 2
    t0 = time.perf_counter()
    for _ in range(reps):
        svc.submit(reqs)
    dt = (time.perf_counter() - t0) / reps
    rows.append((
        f"engine/serving_sync_batched/n={req_len}x{n_req}",
        dt * 1e6,
        f"keys_per_s={keys_total / dt:.0f}",
    ))

    asvc = AsyncSortService(svc, max_batch=n_req, max_delay_ms=2.0)
    for f in [asvc.submit_async(r) for r in reqs]:  # reach steady state
        f.result()
    t0 = time.perf_counter()
    for _ in range(reps):
        futs = [asvc.submit_async(r) for r in reqs]
        for f in futs:
            f.result()
    dt_async = (time.perf_counter() - t0) / reps
    st = asvc.stats
    rows.append((
        f"engine/serving_async_queue/n={req_len}x{n_req}",
        dt_async * 1e6,
        f"keys_per_s={keys_total / dt_async:.0f};fill={st.fill_ratio():.2f};"
        f"queue_p50_ms={st.latency_percentiles()[50] * 1e3:.2f};"
        f"vs_sync={dt / dt_async:.2f}x",
    ))
    asvc.close()

    # adaptive flush window (DelayController): same traffic, the window
    # shrinks as batches fill early — the derived column shows where it
    # settled and what the adaptation paid/earned vs the fixed window
    adsvc = AsyncSortService(svc, max_batch=n_req, max_delay_ms=2.0,
                             min_delay_ms=0.05)
    for f in [adsvc.submit_async(r) for r in reqs]:
        f.result()
    t0 = time.perf_counter()
    for _ in range(reps):
        futs = [adsvc.submit_async(r) for r in reqs]
        for f in futs:
            f.result()
    dt_ad = (time.perf_counter() - t0) / reps
    ctl = adsvc.delay
    rows.append((
        f"engine/serving_async_adaptive/n={req_len}x{n_req}",
        dt_ad * 1e6,
        f"keys_per_s={keys_total / dt_ad:.0f};"
        f"delay_ms={ctl.delay_ms:.3f};shrinks={ctl.shrinks};"
        f"grows={ctl.grows};arrival_rate={ctl.arrival_rate():.0f}/s;"
        f"vs_fixed_async={dt_async / dt_ad:.2f}x",
    ))
    adsvc.close()
    return rows


def moe_rows(rng, *, reps: int, smoke: bool):
    """MoE dispatch through the adaptive exchange engine (docs/exchange.md).

    A worst-case-skewed router (everything collapses onto one hot expert)
    dispatches through ``moe_apply_adaptive``: the first call pays the
    overflow retry and teaches the planner a per-(n_experts, top_k, token
    bucket) capacity factor; the timed steady-state loop then runs at the
    learned factor — the ``derived`` column shows what was learned and that
    the retry was paid exactly once.
    """
    from repro.engine import Planner
    from repro.models.moe import (
        MoEConfig, collapse_router, moe_apply_adaptive, moe_init, moe_plan_key,
    )

    cfg = MoEConfig(d_model=32, d_ff=16, n_experts=8, top_k=2)
    p = collapse_router(
        moe_init(jax.random.PRNGKey(0), cfg, jnp.float32, ep_shards=1), 8.0)
    T = 256 if smoke else 1024
    xs = [jnp.asarray(rng.standard_normal((T, cfg.d_model)), np.float32)
          for _ in range(4)]

    planner = Planner()
    key = moe_plan_key(T, cfg, jnp.float32)
    y, _, _ = moe_apply_adaptive(p, cfg, xs[0], planner=planner)  # pays retry
    first = planner.telemetry.last(key)
    jax.block_until_ready(y)

    t0 = time.perf_counter()
    for i in range(max(reps, 2) * 4):
        y, _, _ = moe_apply_adaptive(p, cfg, xs[i % len(xs)], planner=planner)
    jax.block_until_ready(y)
    dt = (time.perf_counter() - t0) / (max(reps, 2) * 4)
    cf = planner.capacity_factor_for(key, default=cfg.capacity_factor)
    return [(
        f"engine/moe_dispatch_adaptive/T={T}xE{cfg.n_experts}k{cfg.top_k}",
        dt * 1e6,
        f"tokens_per_s={T / dt:.0f};learned_cf={cf:.2f};"
        f"first_call_retries={first.retries};"
        f"steady_retries={planner.telemetry.last(key).retries};"
        f"dropped_averted={planner.telemetry.total_dropped_averted}",
    )]


def frontend_rows(rng, *, reps: int, smoke: bool):
    """Multi-tenant SLO frontend: AOT warm-vs-cold, then overload behaviour.

    Warm-vs-cold replays one wall-clock trace twice — against a cold
    compiled cache (the percentiles eat first-request compile stalls) and
    against an AOT-warmed one (``SortFrontend.warmup``) — so the delta is
    exactly what engine-level warmup buys.  The overload rows are
    deterministic ManualClock discrete-event simulations (seeded trace,
    fixed cost model): byte-for-byte reproducible, which is what makes
    their p50/p95/p99 + goodput values regression-gateable via --compare.
    """
    from repro.engine import SortFrontend, SortService, Tenant, make_trace, run_load
    from repro.engine.adapt import ManualClock
    from repro.engine.frontend import (
        linear_service_time, replay_wallclock, zipf_shares,
    )

    rows = []

    # --- warm vs cold first requests: real executables, wall clock ---------
    sizes = (256, 1024) if smoke else (256, 1024, 4096)
    slo_ms = 250.0
    trace = make_trace(duration_s=0.5 if smoke else 1.5,
                       rates={"web": 30.0}, sizes=sizes, seed=11)
    for mode in ("cold", "warm"):
        fe = SortFrontend(SortService(),
                          tenants=[Tenant("web", slo_ms=slo_ms)],
                          max_batch=8, shed_expired=False, start=True)
        if mode == "warm":
            fe.warmup(cells=[(s, "int32") for s in sizes], kinds=("sort",))
        misses_before = fe.service.cache.stats()["misses"]
        rep = replay_wallclock(fe, trace, seed=11)
        fe.close()
        compiles = fe.service.cache.stats()["misses"] - misses_before
        rows.append((
            f"frontend/serving_{mode}/slo={slo_ms:g}ms",
            rep.latency_percentiles()[95] * 1e6,
            rep.derived() + f";compiles_in_traffic={compiles}",
        ))

    # --- overload simulations: deterministic ManualClock ------------------
    # cost model capacity ~ max_batch / base_ms = 800 req/s; both traces
    # offer 1200 req/s, so the scheduler must shed / miss ~1/3 of load
    cost = linear_service_time(base_ms=5.0, us_per_key=0.02)
    dur = 1.0 if smoke else 3.0

    clk = ManualClock()
    fe = SortFrontend(SortService(), tenants=[Tenant("solo", slo_ms=40.0)],
                      max_batch=4, maxsize=64, clock=clk)
    tr = make_trace(duration_s=dur, rates={"solo": 1200.0},
                    sizes=(256, 512), seed=5)
    rep = run_load(fe, tr, clock=clk, service_time=cost)
    rows.append((
        "frontend/overload_sim_1tenant/rate=1200",
        rep.latency_percentiles()[95] * 1e6,
        rep.derived(),
    ))

    shares = zipf_shares(3, 2.0)   # ~0.73 / 0.18 / 0.08 of the offered load
    names = ("web", "mobile", "batch")
    clk = ManualClock()
    fe = SortFrontend(
        SortService(),
        tenants=[Tenant("web", weight=2.0, priority=0, slo_ms=40.0),
                 Tenant("mobile", weight=1.0, priority=0, slo_ms=40.0),
                 Tenant("batch", weight=1.0, priority=1, slo_ms=200.0)],
        max_batch=4, maxsize=64, clock=clk,
    )
    tr = make_trace(duration_s=dur,
                    rates={n: 1200.0 * s for n, s in zip(names, shares)},
                    sizes=(256, 512), seed=5)
    rep = run_load(fe, tr, clock=clk, service_time=cost)
    rows.append((
        "frontend/overload_sim_3tenant_skew/rate=1200",
        rep.latency_percentiles()[95] * 1e6,
        rep.derived(),
    ))
    for n in names:
        rows.append((
            f"frontend/overload_sim_3tenant_skew/tenant={n}",
            rep.latency_percentiles(tenant=n)[95] * 1e6,
            rep.derived(n),
        ))
    return rows


def skew_rows(rng, mesh, *, reps: int, smoke: bool):
    """Adversarial skew sweep: radix vs sample partition, head to head.

    Each row times model-D ``cluster_sort`` at a fixed ``capacity_factor=2.0``
    on one skewed distribution; the derived column reports the overflow
    retries that partition paid and the peak/mean bucket ratio it produced.
    Reading the pairs: radix rows pay retries and ratios way above 2 on every
    skewed distribution, sample rows hold ratio ~1 with zero retries at the
    same capacity — the balance-vs-simplicity tradeoff docs/exchange.md
    derives, measured (the ``uniform`` pair is the radix-friendly baseline
    showing what sample mode's sampling costs when skew is absent).
    """
    from repro.core.cluster_sort import cluster_sort

    n = 1 << 12 if smoke else 1 << 16
    dists = {
        "uniform": rng.integers(0, 1 << 20, n),       # radix's home turf
        "all_equal": np.full(n, 7),
        "zipf": np.minimum(rng.zipf(1.5, n), 1 << 30),
        "one_hot": np.where(rng.random(n) < 0.95, 1000,
                            rng.integers(0, 8000, n)),
        "clustered": (rng.choice(np.array([0, 3000, 6000]), n)
                      + rng.integers(0, 100, n)),
    }
    rows = []
    for dist, keys in dists.items():
        x = jnp.asarray(keys.astype(np.int32))
        for mode in ("radix", "sample"):
            telem = []

            def run():
                return cluster_sort(
                    x, mesh, "x", mode=mode, capacity_factor=2.0,
                    telemetry=lambda **kw: telem.append(kw),
                )

            jax.block_until_ready(run())   # warmup: compiles + any retries
            t0 = time.perf_counter()
            for _ in range(reps):
                out = run()
            jax.block_until_ready(out)
            us = (time.perf_counter() - t0) / reps * 1e6
            last = telem[-1]
            ratio = last["peak"] * last["part_buckets"] / max(last["m"], 1)
            rows.append((
                f"engine/skew_{mode}/dist={dist}/n={n}",
                us,
                f"retries={last['retries']};peak_ratio={ratio:.2f}",
            ))
    return rows


def gloo_rows(*, reps: int, smoke: bool):
    """Real-wire section (off by default: ``--sections gloo``).

    Runs the same timing body twice — once under 2 real ``jax.distributed``
    processes exchanging over gloo, once on the single-process forced
    2-device mesh every other section uses — via the multihost test harness.
    The shared row is pure local compute and should cost the same either
    way; the cluster row pays genuine inter-process message passing only in
    the gloo run, so its ``wire_cost`` ratio is the real collective tax the
    forced mesh hides.  Spawns subprocesses: slower than the in-process
    sections, and not part of the default or smoke sweeps.
    """
    mh_dir = os.path.abspath(os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "tests", "multihost",
    ))
    sys.path.insert(0, mh_dir)
    try:
        import harness
    finally:
        sys.path.remove(mh_dir)

    n = 1 << 12 if smoke else 1 << 14
    body_args = {"n": n, "reps": reps, "seed": 0}
    spec = "bodies.py:gloo_timing_body"
    gloo = harness.run_multihost(spec, 2, args=body_args)
    gloo.require_success()
    forced = harness.run_forced_mesh(spec, 2, args=body_args)
    forced.require_success()
    g = gloo.reports[0].result      # max-over-ranks: identical on every rank
    f = forced.reports[0].result

    rows = []
    for name in ("shared", "cluster"):
        wire = g[name] / f[name] if f[name] > 0 else float("inf")
        rows.append((
            f"engine/gloo_{name}/n={n}",
            g[name],
            f"forced_us={f[name]:.1f};wire_cost={wire:.2f}x",
        ))
    rows.append((
        f"engine/gloo_crossover/n={n}",
        g["cluster"],
        f"cluster_vs_shared_gloo={g['cluster'] / g['shared']:.2f}x;"
        f"cluster_vs_shared_forced={f['cluster'] / f['shared']:.2f}x",
    ))
    return rows


def parse_derived(derived: str) -> dict:
    """``k=v;k=v`` derived column -> dict (floats where they parse)."""
    out = {}
    for part in filter(None, derived.split(";")):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        try:
            out[k] = float(v[:-1] if v.endswith("x") else v)
        except ValueError:
            out[k] = v
    return out


def write_snapshot(path: str, rows, config: dict) -> None:
    """Persist rows as a BENCH_*.json snapshot (schema: docs/benchmarks.md)."""
    payload = {
        "schema": "repro-engine-bench/v1",
        "created": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "config": config,
        "rows": [
            {"name": name, "us": round(us, 3), "derived": parse_derived(d)}
            for name, us, d in rows
        ],
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"# snapshot written to {path}", file=sys.stderr)


def compare_snapshots(prev_path: str, rows, *, threshold: float,
                      goodput_slack: float = 0.05):
    """Diff current rows against a snapshot; returns the regression list.

    A shared row regresses when its time ratio (new/old) exceeds
    ``threshold`` or its ``goodput`` derived value drops by more than
    ``goodput_slack``.  Rows only one side has are reported but never fail.
    """
    with open(prev_path) as f:
        prev = json.load(f)
    if prev.get("schema") != "repro-engine-bench/v1":
        raise SystemExit(f"unrecognized snapshot schema in {prev_path}")
    prev_rows = {r["name"]: r for r in prev["rows"]}
    regressions = []
    for name, us, d in rows:
        old = prev_rows.pop(name, None)
        if old is None:
            print(f"# compare {name}: new row (no baseline)", file=sys.stderr)
            continue
        ratio = us / old["us"] if old["us"] > 0 else 1.0
        msg = f"# compare {name}: {old['us']:.1f} -> {us:.1f} us ({ratio:.2f}x)"
        if ratio > threshold:
            regressions.append(f"{name}: {ratio:.2f}x slower (>{threshold}x)")
            msg += "  REGRESSION"
        new_gp = parse_derived(d).get("goodput")
        old_gp = old["derived"].get("goodput")
        if isinstance(new_gp, float) and isinstance(old_gp, float):
            msg += f" goodput {old_gp:.3f} -> {new_gp:.3f}"
            if old_gp - new_gp > goodput_slack:
                regressions.append(
                    f"{name}: goodput {old_gp:.3f} -> {new_gp:.3f} "
                    f"(lost >{goodput_slack})"
                )
                msg += "  REGRESSION"
        print(msg, file=sys.stderr)
    for name in prev_rows:
        print(f"# compare {name}: row vanished from this run", file=sys.stderr)
    return regressions


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="tiny sizes for CI")
    ap.add_argument("--sizes", default="", help="comma-separated overrides")
    ap.add_argument("--reps", type=int, default=0, help="0 = auto")
    ap.add_argument("--plans", default="", help="persist tuned plans to this JSON")
    ap.add_argument("--sections", default="crossover,serving,moe,frontend,skew",
                    help="comma-separated row groups to run (off-default "
                         "extra: 'gloo' — real 2-process wire-cost rows)")
    ap.add_argument("--snapshot", default="",
                    help="write rows to this BENCH_*.json")
    ap.add_argument("--compare", default="",
                    help="diff against this snapshot; nonzero exit on regression")
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="time-ratio regression bound for --compare")
    args = ap.parse_args(argv)
    sections = {s.strip() for s in args.sections.split(",") if s.strip()}

    from repro.engine.planner import (
        PALLAS_INTERPRET_MAX,
        Planner,
        SortPlan,
        _time_plan,
        default_plan,
        plan_from_strategy,
    )

    if args.sizes:
        sizes = [int(s) for s in args.sizes.split(",")]
    elif args.smoke:
        sizes = [1 << 12, 1 << 14]
    else:
        sizes = [1 << p for p in (14, 16, 18, 20, 22)]
    reps = args.reps or (1 if args.smoke else 3)

    mesh = jax.make_mesh((len(jax.devices()),), ("x",))
    planner = Planner(args.plans or None)
    rng = np.random.default_rng(0)
    rows = []

    if "crossover" not in sections:
        sizes = []
    strategies = {
        "A_shared_merge": plan_from_strategy("shared_merge"),
        "B_shared_hybrid": plan_from_strategy("shared_hybrid"),
        "B_shared_pallas": SortPlan("shared", local_impl="pallas", block_n=256),
        "C_distributed_merge": plan_from_strategy("distributed_merge"),
        "D_cluster": SortPlan("cluster", capacity_factor=2.0, mode="splitters"),
    }
    interpret_backend = jax.default_backend() != "tpu"
    for n in sizes:
        x = jnp.asarray(rng.integers(100, 1000, size=n).astype(np.int32))
        timings = {}
        for label, plan in strategies.items():
            if (
                interpret_backend
                and plan.local_impl == "pallas"
                and n > PALLAS_INTERPRET_MAX
            ):
                continue  # interpret-mode kernel timings are meaningless at scale
            us = _time_plan(plan, x, mesh, "x", reps=reps)
            timings[label] = us
            rows.append((f"engine/{label}/n={n}", us, ""))

        tuned = planner.autotune(n, jnp.int32, mesh=mesh, axis="x",
                                 quick=args.smoke, reps=reps)
        t_tuned = _time_plan(tuned, x, mesh, "x", reps=reps)
        t_default = _time_plan(default_plan(mesh), x, mesh, "x", reps=reps)
        rows.append(
            (
                f"engine/tuned/n={n}",
                t_tuned,
                f"plan={tuned.strategy}:{tuned.local_impl};"
                f"vs_default={t_default / t_tuned:.2f}x",
            )
        )
        rows.append((f"engine/default_rule/n={n}", t_default, ""))

    if "serving" in sections:
        rows += serving_rows(rng, reps=max(reps, 2), smoke=args.smoke)
    if "moe" in sections:
        rows += moe_rows(rng, reps=reps, smoke=args.smoke)
    if "frontend" in sections:
        rows += frontend_rows(rng, reps=max(reps, 2), smoke=args.smoke)
    if "skew" in sections:
        rows += skew_rows(rng, mesh, reps=max(reps, 2), smoke=args.smoke)
    if "gloo" in sections:
        rows += gloo_rows(reps=max(reps, 2), smoke=args.smoke)

    if args.plans:
        planner.save()
        print(f"# tuned plans saved to {args.plans}", file=sys.stderr)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if args.snapshot:
        write_snapshot(args.snapshot, rows, {
            "smoke": args.smoke, "sizes": args.sizes, "reps": reps,
            "sections": sorted(sections),
        })
    if args.compare:
        regressions = compare_snapshots(args.compare, rows,
                                        threshold=args.threshold)
        if regressions:
            for r in regressions:
                print(f"REGRESSION: {r}", file=sys.stderr)
            raise SystemExit(1)
        print(f"# compare vs {args.compare}: no regressions", file=sys.stderr)
    return rows


if __name__ == "__main__":
    main()
