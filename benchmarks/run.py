"""Benchmark harness: one function per paper table/figure + roofline summary.

Prints ``name,us_per_call,derived`` CSV rows (harness contract), then the
roofline table if dry-run artifacts exist.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --quick    # smaller sizes
  PYTHONPATH=src python -m benchmarks.run --only fig5,fig7
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import figures  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    benches = {
        "fig5": lambda: figures.fig5_sequential(
            sizes=(400_000, 1_000_000) if args.quick else (1_000_000, 4_000_000, 10_000_000)
        ),
        "fig6": lambda: figures.fig6_shared_threads(
            n=1_000_000 if args.quick else 4_000_000,
            threads=(1, 4, 16) if args.quick else (1, 2, 4, 8, 16, 32),
        ),
        "fig7": lambda: figures.fig7_vs_radix_baseline(
            sizes=(400_000,) if args.quick else (1_000_000, 4_000_000)
        ),
        "fig8": lambda: figures.fig8_distributed(n=400_000 if args.quick else 1_000_000),
        "fig9_11": lambda: figures.fig9_11_cluster_scaling(
            sizes=(400_000,) if args.quick else (400_000, 1_000_000, 4_000_000),
            Ps=(2, 8),
        ),
    }

    print("name,us_per_call,derived")
    for key, fn in benches.items():
        if only and key not in only:
            continue
        for name, us, derived in fn():
            print(f"{name},{us:.1f},{derived}")

    if (only is None or "roofline" in only) and os.path.isdir("artifacts/dryrun"):
        print("\n# Roofline (single pod) — see EXPERIMENTS.md §Roofline")
        from benchmarks import roofline

        cells = roofline.analyse()
        print(roofline.table(cells, "pod"))


if __name__ == "__main__":
    main()
