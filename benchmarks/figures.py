"""One benchmark per paper table/figure (Alghamdi & Alaghband 2020).

Honesty note (recorded in EXPERIMENTS.md): this container exposes ONE physical
core, so multi-"device"/multi-block wall-clock does not show real parallel
speedup — host devices time-share the core. What these benchmarks measure
faithfully is the *algorithmic* comparison the paper makes (hybrid vs
non-hybrid local sort, partition-first vs merge-tree data movement) on
identical hardware; the roofline analysis covers the scaling story.

Every function returns rows of (name, us_per_call, derived) for run.py's CSV.
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _timeit(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def _data(n, seed=0):
    """Paper §4.2: random 3-digit integers (100..999)."""
    return np.random.default_rng(seed).integers(100, 1000, size=n).astype(np.int32)


# ---------------------------------------------------------------- figure 5 ---
def fig5_sequential(sizes=(1_000_000, 4_000_000, 10_000_000)):
    """Sequential sorts: recursive merge vs non-recursive merge vs 'quicksort'
    (XLA sort plays the fastest-local-sort role; bitonic = the kernel network).
    Paper: quicksort 1.76x faster than recursive merge at 10M."""
    from repro.core import fast_local_sort, nonrecursive_merge_sort, recursive_merge_sort_host

    rows = []
    for n in sizes:
        x = _data(n)
        xj = jnp.asarray(x)
        t0 = time.perf_counter()
        recursive_merge_sort_host(x)
        t_rec = (time.perf_counter() - t0) * 1e6
        t_nonrec = _timeit(jax.jit(nonrecursive_merge_sort), xj)
        t_quick = _timeit(jax.jit(lambda v: fast_local_sort(v, impl="xla")), xj)
        t_bit = _timeit(jax.jit(lambda v: fast_local_sort(v, impl="bitonic")), xj)
        rows += [
            (f"fig5/recursive_merge/n={n}", t_rec, ""),
            (f"fig5/nonrecursive_merge/n={n}", t_nonrec, f"vs_rec={t_rec/t_nonrec:.2f}x"),
            (f"fig5/quicksort_role_xla/n={n}", t_quick, f"vs_rec={t_rec/t_quick:.2f}x"),
            (f"fig5/bitonic_network/n={n}", t_bit, f"vs_rec={t_rec/t_bit:.2f}x"),
        ]
    return rows


# ---------------------------------------------------------------- figure 6 ---
def fig6_shared_threads(n=4_000_000, threads=(1, 2, 4, 8, 16, 32)):
    """Shared-memory models A vs B across 'thread' (block) counts."""
    from repro.core import shared_memory_sort

    x = jnp.asarray(_data(n))
    base = _timeit(jax.jit(jnp.sort), x)
    rows = [(f"fig6/sequential_xla/n={n}", base, "speedup=1.00")]
    for t in threads:
        for impl, label in (("merge", "A_nonrec_merge"), ("xla", "B_hybrid_quick_merge")):
            us = _timeit(
                jax.jit(lambda v, tt=t, ii=impl: shared_memory_sort(v, n_threads=tt, local_impl=ii)),
                x,
            )
            rows.append((f"fig6/{label}/t={t}/n={n}", us, f"speedup={base/us:.2f}"))
    return rows


# ---------------------------------------------------------------- figure 7 ---
def fig7_vs_radix_baseline(sizes=(1_000_000, 4_000_000)):
    """Our hybrid (model B) vs the Aydin & Alaghband baseline the paper beats:
    one-step MSD-Radix into 10 buckets, then 'quicksort' per bucket.
    Paper: model B 2.55x faster at 4M / 8 threads."""
    from repro.core import shared_memory_sort
    from repro.core.radix import decimal_msd_bucket

    def radix_quick_baseline(x):
        bucket = decimal_msd_bucket(x, digits=3)
        cap = x.shape[0]  # loss-free capacity
        order = jnp.argsort(bucket, stable=True)
        xs = x[order]
        counts = jnp.bincount(bucket, length=10)
        offs = jnp.concatenate([jnp.zeros(1, jnp.int32), jnp.cumsum(counts)[:-1].astype(jnp.int32)])
        pos = jnp.arange(x.shape[0], dtype=jnp.int32) - offs[bucket[order]]
        slab = jnp.full((10, cap), jnp.iinfo(jnp.int32).max, jnp.int32)
        slab = slab.at[bucket[order], pos].set(xs)
        slab = jnp.sort(slab, axis=-1)  # per-bucket "quicksort"
        return slab  # concatenation of valid prefixes is the sorted array

    rows = []
    for n in sizes:
        x = jnp.asarray(_data(n))
        t_base = _timeit(jax.jit(radix_quick_baseline), x)
        t_ours = _timeit(
            jax.jit(lambda v: shared_memory_sort(v, n_threads=8, local_impl="xla")), x
        )
        rows += [
            (f"fig7/baseline_msdradix_quick/n={n}", t_base, ""),
            (f"fig7/ours_hybrid_quick_merge/n={n}", t_ours, f"ours_vs_baseline={t_base/t_ours:.2f}x"),
        ]
    return rows


# ----------------------------------------------------------- figures 8-11 ---
_DISTRIBUTED_SNIPPET = """
import time, numpy as np, jax, jax.numpy as jnp
from repro.core import distributed_merge_sort, cluster_sort, shared_memory_sort
P = {P}; n = {n}
mesh = jax.make_mesh((P,), ("x",))
x = jnp.asarray(np.random.default_rng(0).integers(100, 1000, size=n).astype(np.int32))

def timeit(fn):
    out = fn(); jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(3): out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / 3 * 1e6

t_seq = timeit(lambda: jnp.sort(x))
t_shared = timeit(lambda: shared_memory_sort(x, n_threads=4, local_impl="xla"))
t_c = timeit(lambda: distributed_merge_sort(x, mesh, "x"))
t_d = timeit(lambda: cluster_sort(x, mesh, "x", mode="range", lo=100, hi=1000,
                                  capacity_factor=1.5)[0])
print(f"RESULT,{{t_seq:.1f}},{{t_shared:.1f}},{{t_c:.1f}},{{t_d:.1f}}")
"""


def _run_distributed(P, n):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={P}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", _DISTRIBUTED_SNIPPET.format(P=P, n=n)],
        env=env, capture_output=True, text=True, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT")][0]
    return [float(v) for v in line.split(",")[1:]]


def fig8_distributed(n=1_000_000, P=4):
    """Model C (distributed merge tree) vs shared-memory B vs sequential."""
    t_seq, t_shared, t_c, t_d = _run_distributed(P, n)
    return [
        (f"fig8/sequential/n={n}", t_seq, "speedup=1.00"),
        (f"fig8/B_shared_hybrid/t=4/n={n}", t_shared, f"speedup={t_seq/t_shared:.2f}"),
        (f"fig8/C_distributed_merge/P={P}/n={n}", t_c, f"speedup={t_seq/t_c:.2f}"),
        (f"fig8/D_cluster/P={P}/n={n}", t_d, f"speedup={t_seq/t_d:.2f}"),
    ]


def fig9_11_cluster_scaling(sizes=(400_000, 1_000_000, 4_000_000), Ps=(2, 8)):
    """Model D across data sizes and 'node' counts (paper figs 9-11: D's
    speedup grows with size; more nodes win only past ~4M)."""
    rows = []
    for n in sizes:
        for P in Ps:
            t_seq, _, t_c, t_d = _run_distributed(P, n)
            rows.append(
                (f"fig9_11/D_cluster/P={P}/n={n}", t_d,
                 f"speedup={t_seq/t_d:.2f};C_speedup={t_seq/t_c:.2f}")
            )
    return rows
