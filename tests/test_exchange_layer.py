"""The unified exchange layer (repro.exchange): capacity math shared by sort
and MoE dispatch, the generalized retry driver's strict/drop contracts, the
back-compat re-exports, and an in-process single-device wire round-trip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare container — requirements-dev.txt installs the real one
    from _hypothesis_shim import given, settings, strategies as st

from repro.exchange import (
    ExchangeObservation,
    ExchangeTelemetry,
    combine_exchange,
    expert_capacity,
    partition_exchange,
    run_with_capacity_retries,
    sentinel_for,
    slab_capacity,
    slab_geometry,
    slab_valid,
)

settings.register_profile("repro-ci", max_examples=10, deadline=None,
                          derandomize=True)
settings.load_profile("repro-ci")

ms = st.integers(0, 1 << 14)   # 0 = a drained sender (empty shard/microbatch)
buckets = st.integers(1, 64)
cfs = st.floats(0.05, 64.0)
Ts = st.integers(0, 1 << 10)   # 0 = an empty token batch
ks = st.integers(1, 4)
Es = st.integers(1, 64)


# ------------------------------------------------------------ capacity math ---
@given(ms, buckets, cfs)
def test_slab_capacity_bounds_and_monotonicity(m, b, cf):
    """THE capacity formula: within [1, m] always, monotone in the factor,
    and >= a uniform sender's per-bucket load whenever cf >= 1."""
    cap = slab_capacity(m, b, cf)
    assert 1 <= cap <= max(m, 1)   # m=0: the 1-slot floor beats the m bound
    assert slab_capacity(m, b, cf * 2) >= cap
    if cf >= 1.0:
        assert cap * b >= m


@given(ms, st.sampled_from(("decimal", "splitters", "range")),
       st.integers(1, 64), cfs)
def test_slab_geometry_is_keyed_slab_capacity(m, mode, P, cf):
    """slab_geometry's capacity IS slab_capacity at its bucket count — the
    sort path cannot drift from the shared formula."""
    part, n_buckets, cap = slab_geometry(mode, m, P, cf)
    assert cap == slab_capacity(m, part, cf)


@given(Ts, ks, Es, cfs)
def test_expert_capacity_is_keyed_slab_capacity(T, k, E, cf):
    """The hoisted MoE formula (was duplicated verbatim at moe.py:100/161)
    is slab_capacity keyed by (tokens*top_k, n_experts): same ceil, same
    [1, m] clamp, same monotonicity."""
    cap = expert_capacity(T, k, E, cf)
    assert cap == slab_capacity(T * k, E, cf)
    assert 1 <= cap <= max(T * k, 1)
    assert expert_capacity(T, k, E, cf * 2) >= cap


def test_expert_capacity_never_zero():
    """Regression: an empty shard/microbatch used to get a zero-capacity
    slab (min(m, ...) with m=0), which the retry driver doubles forever —
    0*2 is still 0 — until retries exhaust.  The floor must win."""
    assert expert_capacity(0, 2, 8, 1.25) == 1
    assert slab_capacity(0, 8, 1.25) == 1
    assert slab_geometry("splitters", 0, 8, 1.5)[2] == 1


def test_slab_valid_masks_per_shard_prefixes():
    got = [bool(b) for b in slab_valid(8, jnp.array([1, 3]), 2)]
    assert got == [True, False, False, False, True, True, True, False]


def test_sentinel_for_back_compat_reexport():
    """core.bitonic grew up owning sentinel_for; the exchange layer is its
    home now and core re-exports the same object."""
    from repro.core.bitonic import sentinel_for as core_sentinel

    assert core_sentinel is sentinel_for
    assert int(sentinel_for(jnp.int16, largest=False)) == jnp.iinfo(jnp.int16).min
    with pytest.raises(TypeError):
        sentinel_for(jnp.complex64, largest=True)


def test_core_and_engine_reexport_the_exchange_layer():
    """ISSUE acceptance: cluster_sort and moe consume repro.exchange — the
    historical import paths must resolve to the very same objects."""
    import sys

    import repro.core.cluster_sort  # noqa: F401  (the function shadows the
    import repro.engine.adapt       # module attr on the package, go via sys)
    import repro.exchange as ex

    cs = sys.modules["repro.core.cluster_sort"]
    adapt = sys.modules["repro.engine.adapt"]
    assert cs.partition_exchange is ex.partition_exchange
    assert cs.combine_exchange is ex.combine_exchange
    assert cs.slab_geometry is ex.slab_geometry
    assert cs.run_with_capacity_retries is ex.run_with_capacity_retries
    assert adapt.ExchangeObservation is ex.ExchangeObservation
    assert adapt.ExchangeTelemetry is ex.ExchangeTelemetry


# ------------------------------------------------------------- retry driver ---
def _toy_driver(*, strict, max_retries, fits_at, cap0=1, m=16):
    """Drive the retry loop with a fake executable that overflows until
    capacity reaches ``fits_at``; records every telemetry report."""
    from functools import lru_cache

    reports = []

    @lru_cache(maxsize=None)
    def make(cap):
        return cap

    def run(cap):
        counts = jnp.array([min(fits_at, m)])
        return jnp.arange(4), counts, jnp.asarray(fits_at), jnp.asarray(cap < fits_at)

    outs, counts = run_with_capacity_retries(
        make, run, m=m, part_buckets=1, cap=cap0,
        max_retries=max_retries, telemetry=lambda **kw: reports.append(kw),
        lru=make, label="toy", strict=strict)
    return outs, counts, reports


def test_retry_driver_returns_counts_and_reports_once():
    outs, counts, reports = _toy_driver(strict=True, max_retries=4, fits_at=3)
    assert int(counts[0]) == 3 and len(outs) == 1
    assert len(reports) == 1
    assert reports[0]["retries"] == 2 and reports[0]["overflowed"]
    assert reports[0]["capacity"] == 4 and reports[0]["peak"] == 3


def test_retry_driver_strict_raises_on_persistent_overflow():
    with pytest.raises(RuntimeError, match="toy"):
        _toy_driver(strict=True, max_retries=1, fits_at=100, m=8)


def test_retry_driver_nonstrict_degrades_to_drop():
    """The MoE contract: exhausted retries return the last attempt (GShard
    overflow-drop semantics) with the overflow reported, instead of dying."""
    outs, counts, reports = _toy_driver(strict=False, max_retries=1, fits_at=100, m=8)
    assert len(outs) == 1 and int(counts[0]) == 8
    assert len(reports) == 1 and reports[0]["overflowed"]
    assert reports[0]["retries"] == 1


def test_retry_driver_stops_at_loss_free_bound():
    """cap >= m is loss-free for real exchanges; the driver must not burn
    the remaining retry budget once it gets there."""
    outs, counts, reports = _toy_driver(
        strict=False, max_retries=10, fits_at=100, m=4)
    # cap walk: 1 -> 2 -> 4 == m, then stop (3 attempts, not 11)
    assert reports[0]["capacity"] == 4 and reports[0]["retries"] == 2


# ------------------------------------------------- telemetry drop accounting ---
def test_telemetry_ledger_tracks_dropped_elements():
    led = ExchangeTelemetry()
    led.record("moe/E8k2|64|float32|local/cpu", ExchangeObservation(
        m=128, part_buckets=8, capacity=16, peak=48, overflowed=True,
        retries=0, dropped=32))              # fixed path: real served loss
    led.record("moe/E8k2|64|float32|local/cpu", ExchangeObservation(
        m=128, part_buckets=8, capacity=64, peak=48, overflowed=True,
        retries=1, dropped_averted=32))      # adaptive path: retried away
    assert led.total_dropped == 32
    assert led.total_dropped_averted == 32
    assert led.overflow_events == 2
    assert led.last("moe/E8k2|64|float32|local/cpu").dropped == 0


# ------------------------------------------------ in-process wire round-trip ---
def test_exchange_roundtrip_single_device_mesh(rng):
    """The collective contract on a 1-device mesh (runs in-process, so the
    wire code is exercised under coverage, not only in subprocess tests):
    values follow keys, combine restores order, overflow drops get fill."""
    mesh = jax.make_mesh((jax.device_count(),), ("x",))
    P = jax.device_count()
    n, B = 16, 4
    keys = jnp.asarray(rng.integers(0, B * P, n), jnp.int32)
    vals = jnp.asarray(rng.standard_normal((n, 3)), jnp.float32)

    from jax.sharding import PartitionSpec as PS

    def roundtrip(k, v):
        ex = partition_exchange(k, v, k % (B * P), "x", capacity=n,
                                n_buckets=B * P)
        return combine_exchange(ex.recv_values, ex, "x"), ex.overflow

    out, ovf = jax.jit(jax.shard_map(
        roundtrip, mesh=mesh, in_specs=(PS("x"), PS("x")),
        out_specs=(PS("x"), PS())))(keys, vals)
    assert not bool(ovf)
    np.testing.assert_allclose(np.asarray(out), np.asarray(vals))

    def tight(k, v):  # capacity 1: heavy duplicate keys must overflow + drop
        ex = partition_exchange(k, v, jnp.zeros_like(k), "x", capacity=1,
                                n_buckets=B * P)
        return combine_exchange(ex.recv_values, ex, "x", fill=-7.0), ex.overflow

    out, ovf = jax.jit(jax.shard_map(
        tight, mesh=mesh, in_specs=(PS("x"), PS("x")),
        out_specs=(PS("x"), PS())))(keys, vals)
    assert bool(ovf)
    dropped_rows = (np.asarray(out) == -7.0).all(axis=1)
    assert dropped_rows.sum() == n - P  # one survivor per sender


def test_exchange_compress_roundtrip_single_device_mesh(rng):
    """compress=True quantizes float payloads to int8 on the wire; integer
    leaves must stay exact."""
    mesh = jax.make_mesh((jax.device_count(),), ("x",))
    n = 16
    keys = jnp.asarray(rng.integers(0, jax.device_count(), n), jnp.int32)
    vals = {"f": jnp.asarray(rng.standard_normal((n, 2)), jnp.float32),
            "i": jnp.asarray(np.arange(n), jnp.int32)}

    from jax.sharding import PartitionSpec as PS

    def roundtrip(k, v):
        ex = partition_exchange(k, v, k, "x", capacity=n, compress=True)
        return combine_exchange(ex.recv_values, ex, "x")

    out = jax.jit(jax.shard_map(
        roundtrip, mesh=mesh,
        in_specs=(PS("x"), {"f": PS("x"), "i": PS("x")}),
        out_specs={"f": PS("x"), "i": PS("x")}))(keys, vals)
    assert (np.asarray(out["i"]) == np.arange(n)).all()  # ints: exact
    np.testing.assert_allclose(  # floats: int8-quantized, ~1% of row max
        np.asarray(out["f"]), np.asarray(vals["f"]), atol=0.05)
