"""AsyncSortService: cross-caller coalescing, backpressure, lifecycle, stats.

Every timing-sensitive case runs on ``ManualClock`` — the injected monotonic
clock the queue reads for enqueue stamps, flush deadlines, latencies, and
delay adaptation.  Time moves only when a test calls ``advance``, so batch
boundaries are decided by the test, not by wall-clock races: a frozen clock
means groups flush *only* when full (or at close), and advancing past a
deadline flushes exactly the groups whose deadline passed.  No test in this
file sleeps or asserts on real elapsed time except the throughput-accounting
regression, which is explicitly about real wall time.
"""
import queue as stdqueue
import threading
import time

import numpy as np
import pytest

from repro.engine import (
    AsyncSortService,
    DelayController,
    ManualClock,
    QueueStats,
    SortService,
)


def _mk(rng, n):
    return rng.integers(0, 1_000_000, n).astype(np.int32)


# ------------------------------------------------------------- coalescing ---
def test_concurrent_producers_coalesce_into_one_executable_call():
    """Acceptance: N concurrent single-request producers of the same bucket
    execute as ONE batch (fewer than N), with zero recompiles after warmup —
    asserted with jax's lowering counter, not just our own stats.  The frozen
    ManualClock makes the coalescing deterministic: nothing can flush before
    the batch is full, no matter how the threads interleave."""
    from jax._src import test_util as jtu

    N = 8
    rng = np.random.default_rng(0)
    svc = AsyncSortService(max_batch=N, clock=ManualClock())
    # warmup: same bucket, same coalesced batch shape -> compiles (N, 1024)
    futs = [svc.submit_async(_mk(rng, 1000)) for _ in range(N)]
    for f in futs:
        f.result(timeout=120)
    batches_before = svc.stats.batches

    reqs = [_mk(rng, 900 + i) for i in range(N)]  # same 1024 bucket
    results = [None] * N

    def producer(i):
        results[i] = svc.submit_async(reqs[i]).result(timeout=120)

    with jtu.count_jit_and_pmap_lowerings() as count:
        threads = [threading.Thread(target=producer, args=(i,)) for i in range(N)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    assert count[0] == 0, "steady-state async path must not re-trace"
    executed = svc.stats.batches - batches_before
    assert executed < N, "cross-caller requests must coalesce"
    assert executed == 1  # frozen clock: only a full batch can flush
    for r, o in zip(reqs, results):
        assert (o == np.sort(r)).all()
    # QueueStats saw the coalesced batch
    st = svc.stats
    assert isinstance(st, QueueStats)
    assert st.coalesced_requests >= 2 * N and st.coalesced_batches >= 2
    assert st.batch_sizes[-1] == N and st.fill_ratio() > 0.9
    pct = st.latency_percentiles()
    assert 0 <= pct[50] <= pct[99]
    svc.close()


def test_many_threads_many_requests_correct_and_order_stable():
    """Stress: mixed kinds/buckets from many threads; every future resolves
    to its own request's oracle (no cross-request mixups under coalescing).
    Submission happens with the clock frozen, so partial groups pile up;
    one clock advance past the window then releases everything."""
    rng = np.random.default_rng(1)
    clock = ManualClock()
    svc = AsyncSortService(max_batch=16, max_delay_ms=5.0, clock=clock)
    per_thread = 6
    n_threads = 6
    payloads = [
        [_mk(np.random.default_rng(100 * t + j), 50 + 37 * (j % 4))
         for j in range(per_thread)]
        for t in range(n_threads)
    ]
    futs = [[] for _ in range(n_threads)]
    errors = []

    def producer(t):
        try:
            for j, r in enumerate(payloads[t]):
                if j % 3 == 0:
                    futs[t].append(("argsort", r, None,
                                    svc.submit_async(r, kind="argsort")))
                elif j % 3 == 1:
                    v = np.arange(len(r), dtype=np.int32)
                    futs[t].append(
                        ("sort_kv", r, v,
                         svc.submit_async(r, kind="sort_kv", values=v))
                    )
                else:
                    futs[t].append(("sort", r, None, svc.submit_async(r)))
        except Exception as e:  # pragma: no cover - surfaced via assert below
            errors.append(e)

    threads = [threading.Thread(target=producer, args=(t,)) for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    clock.advance(1.0)  # all deadlines pass; dispatcher flushes every group
    for t in range(n_threads):
        for kind, r, v, f in futs[t]:
            ref = np.argsort(r, kind="stable")
            if kind == "sort":
                assert (f.result(timeout=120) == np.sort(r)).all()
            elif kind == "argsort":
                assert (f.result(timeout=120) == ref).all()
            else:
                sk, sv = f.result(timeout=120)
                assert (sk == r[ref]).all() and (sv == ref).all()
    assert svc.stats.requests == n_threads * per_thread
    assert svc.stats.coalesced_batches < n_threads * per_thread  # some merging
    svc.close()


# ----------------------------------------------------------- backpressure ---
def test_backpressure_reject_policy_raises_queue_full():
    svc = AsyncSortService(maxsize=2, on_full="reject", start=False,
                           max_batch=2, clock=ManualClock())
    rng = np.random.default_rng(2)
    f1 = svc.submit_async(_mk(rng, 100))
    f2 = svc.submit_async(_mk(rng, 100))
    with pytest.raises(stdqueue.Full):
        svc.submit_async(_mk(rng, 100))
    assert svc.stats.rejected == 1 and svc.stats.enqueued == 2
    svc.start()  # dispatcher drains the two admitted requests (full batch)
    assert f1.result(timeout=120) is not None
    assert f2.result(timeout=120) is not None
    svc.close()


def test_backpressure_block_policy_completes_everything():
    """maxsize=1 + blocking producers: submits stall instead of failing, and
    every request still resolves correctly. The frozen clock pins the flush
    pattern: exactly three full max_batch=4 batches, nothing else."""
    rng = np.random.default_rng(3)
    svc = AsyncSortService(maxsize=1, on_full="block", max_batch=4,
                           clock=ManualClock())
    reqs = [_mk(rng, 200) for _ in range(12)]
    futs = [svc.submit_async(r) for r in reqs]
    for r, f in zip(reqs, futs):
        assert (f.result(timeout=120) == np.sort(r)).all()
    assert svc.stats.rejected == 0 and svc.stats.enqueued == 12
    assert list(svc.stats.batch_sizes)[-3:] == [4, 4, 4]
    svc.close()


# -------------------------------------------------------- drain and close ---
def test_drain_then_close_then_submit_raises():
    rng = np.random.default_rng(4)
    svc = AsyncSortService(max_batch=4, clock=ManualClock())
    futs = [svc.submit_async(_mk(rng, 300)) for _ in range(8)]  # 2 full batches
    assert svc.drain(timeout=120)
    assert all(f.done() for f in futs)
    svc.close()
    svc.close()  # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit_async(_mk(rng, 10))


def test_close_resolves_backlog_of_never_started_service():
    """close() on a staged (start=False) service must not strand futures —
    even with a frozen clock whose deadlines can never fire."""
    rng = np.random.default_rng(5)
    svc = AsyncSortService(start=False, max_batch=64, clock=ManualClock())
    futs = [svc.submit_async(_mk(rng, 64)) for _ in range(3)]
    svc.close()  # starts, drains (flushing the half-empty batch), stops
    assert all(f.done() for f in futs)
    assert svc.stats.batch_sizes[-1] == 3  # flushed below max_batch on close


def test_context_manager_and_execution_error_propagates_to_futures():
    rng = np.random.default_rng(6)
    with AsyncSortService(max_batch=2, clock=ManualClock()) as svc:
        ok = [svc.submit_async(_mk(rng, 50)) for _ in range(2)]  # full batch
        assert all(len(f.result(timeout=120)) == 50 for f in ok)
        # inject an execution failure: every future in the batch must carry it
        boom = RuntimeError("injected")

        def exploding(*a, **k):
            raise boom

        svc.service._run_group = exploding
        bad = [svc.submit_async(_mk(rng, 50)) for _ in range(2)]
        for f in bad:
            assert f.exception(timeout=120) is boom
    with pytest.raises(RuntimeError):
        svc.submit_async(_mk(rng, 10))  # context exit closed it


def test_validation_errors_raise_synchronously():
    svc = AsyncSortService(start=False, clock=ManualClock())
    with pytest.raises(ValueError, match="NaN"):
        svc.submit_async(np.array([1.0, np.nan], np.float32))
    with pytest.raises(ValueError):
        svc.submit_async(np.zeros((2, 2), np.int32))  # not 1-D
    with pytest.raises(ValueError):
        svc.submit_async(np.arange(4), kind="sort_kv")  # missing values
    with pytest.raises(ValueError):
        svc.submit_async(np.arange(4), kind="nope")
    assert svc.stats.enqueued == 0
    svc.close()


# ------------------------------------------------------- stats accounting ---
def test_elapsed_accounting_stays_meaningful_under_concurrent_submitters():
    """Regression for summed-overlapping-spans accounting: N threads hammering
    one SortService must report busy time <= real wall time (interval union),
    so throughput_keys_per_s stays a real keys/sec figure.  (Deliberately on
    the real clock: the property under test is about wall time.)"""
    svc = SortService()
    rng = np.random.default_rng(7)
    reqs = [rng.integers(0, 1000, 2000).astype(np.int32) for _ in range(4)]
    svc.submit(reqs)  # warmup compile outside the timed window
    svc.stats.elapsed_s = 0.0

    N = 6
    t0 = time.perf_counter()

    def hammer():
        for _ in range(5):
            svc.submit(reqs)

    threads = [threading.Thread(target=hammer) for _ in range(N)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    assert 0 < svc.stats.elapsed_s <= wall * 1.05, (svc.stats.elapsed_s, wall)
    assert svc.stats.throughput_keys_per_s() > 0


def test_cancelled_future_is_skipped_without_killing_the_dispatcher():
    """Caller-side Future.cancel() on a queued request: the request is
    dropped, its batchmates still execute, and the dispatcher keeps serving."""
    rng = np.random.default_rng(8)
    clock = ManualClock()
    svc = AsyncSortService(start=False, max_batch=2, clock=clock)
    r1, r2 = _mk(rng, 40), _mk(rng, 40)
    f1 = svc.submit_async(r1)
    f2 = svc.submit_async(r2)
    assert f1.cancel()
    svc.start()  # the pair fills max_batch; the cancelled member is skipped
    assert (f2.result(timeout=120) == np.sort(r2)).all()
    assert f1.cancelled()
    r3 = _mk(rng, 40)
    f3 = svc.submit_async(r3)
    clock.advance(1.0)  # a lone request needs its deadline to pass
    assert (f3.result(timeout=120) == np.sort(r3)).all()
    svc.close()


def test_caller_may_reuse_its_buffer_after_submit_async():
    """submit_async snapshots the request: mutating the caller's array while
    the request waits in the coalescing window must not corrupt the result."""
    rng = np.random.default_rng(9)
    clock = ManualClock()
    svc = AsyncSortService(start=False, max_batch=8, clock=clock)
    buf = _mk(rng, 128)
    want = np.sort(buf)
    vbuf = np.arange(128, dtype=np.int32)
    ref = np.argsort(buf, kind="stable")
    f = svc.submit_async(buf)
    fkv = svc.submit_async(buf, kind="sort_kv", values=vbuf)
    buf[:] = -1  # caller reuses its buffer before the batch executes
    vbuf[:] = -1
    clock.advance(1.0)  # deadlines pass the moment the dispatcher looks
    svc.start()
    assert (f.result(timeout=120) == want).all()
    sk, sv = fkv.result(timeout=120)
    assert (sv == ref).all()
    svc.close()


# ------------------------------------------------------- adaptive window ---
def test_delay_controller_adapts_step_by_step():
    """Pure unit test of the policy on a manual clock: every decision is a
    deterministic function of the observed flushes, replayed step by step."""
    clock = ManualClock()
    ctl = DelayController(1.0, 8.0, clock=clock)
    assert ctl.delay_ms == 8.0  # starts patient (max_delay)

    # full batches before the deadline: shrink geometrically to the floor
    for want in (4.0, 2.0, 1.0, 1.0):
        ctl.observe_flush(n_requests=8, capacity=8, deadline_hit=False)
        assert ctl.delay_ms == pytest.approx(want)
    assert ctl.shrinks == 4

    # sparse deadline flushes: grow geometrically back to the ceiling
    for want in (1.5, 2.25, 3.375):
        ctl.observe_flush(n_requests=1, capacity=8, deadline_hit=True)
        assert ctl.delay_ms == pytest.approx(want)
    assert ctl.grows == 3

    # the middle regime holds: a decently-filled deadline flush, or a
    # below-capacity batch that didn't hit its deadline, changes nothing
    ctl.observe_flush(n_requests=5, capacity=8, deadline_hit=True)
    ctl.observe_flush(n_requests=5, capacity=8, deadline_hit=False)
    assert ctl.delay_ms == pytest.approx(3.375)

    # arrival rate comes straight off the injected clock
    for _ in range(5):
        ctl.note_arrival()
        clock.advance(0.1)
    assert ctl.arrival_rate() == pytest.approx(10.0)

    with pytest.raises(ValueError):
        DelayController(0.0, 8.0)
    with pytest.raises(ValueError):
        DelayController(9.0, 8.0)
    with pytest.raises(ValueError):
        DelayController(1.0, 8.0, shrink=1.5)


def test_adaptive_queue_shrinks_on_full_batches_and_grows_on_sparse():
    """Integration: the queue's effective window follows the traffic shape —
    full batches shrink it, sparse deadline flushes grow it, close-time
    flushes leave it alone. All on the fake clock, no sleeps."""
    rng = np.random.default_rng(10)
    clock = ManualClock()
    svc = AsyncSortService(max_batch=4, max_delay_ms=8.0, min_delay_ms=1.0,
                           clock=clock)
    assert svc.delay is not None and svc.delay_s == pytest.approx(8e-3)

    # a full batch flushes before its (frozen-clock) deadline -> shrink
    futs = [svc.submit_async(_mk(rng, 64)) for _ in range(4)]
    for f in futs:
        f.result(timeout=120)
    assert svc.delay.delay_ms == pytest.approx(4.0)
    assert svc.delay.shrinks == 1 and svc.delay.grows == 0

    # a lone request times out its (shrunken) window -> sparse flush, grow
    f = svc.submit_async(_mk(rng, 64))
    clock.advance(0.005)  # past the 4 ms window
    f.result(timeout=120)
    assert svc.delay.delay_ms == pytest.approx(6.0)
    assert svc.delay.grows == 1

    # arrival tracking rode along on the same clock
    assert svc.delay.arrival_rate() >= 0.0

    # a half-empty batch flushed by close() must not adapt the window
    svc.submit_async(_mk(rng, 64))
    svc.close()
    assert svc.delay.delay_ms == pytest.approx(6.0)
    assert svc.delay.shrinks == 1 and svc.delay.grows == 1


def test_fixed_window_service_has_no_controller():
    svc = AsyncSortService(start=False, max_delay_ms=3.0, clock=ManualClock())
    assert svc.delay is None
    assert svc.delay_s == pytest.approx(3e-3)
    svc.close()
