"""AsyncSortService: cross-caller coalescing, backpressure, lifecycle, stats."""
import queue as stdqueue
import threading
import time

import numpy as np
import pytest

from repro.engine import AsyncSortService, QueueStats, SortService


def _mk(rng, n):
    return rng.integers(0, 1_000_000, n).astype(np.int32)


# ------------------------------------------------------------- coalescing ---
def test_concurrent_producers_coalesce_into_one_executable_call():
    """Acceptance: N concurrent single-request producers of the same bucket
    execute as ONE batch (fewer than N), with zero recompiles after warmup —
    asserted with jax's lowering counter, not just our own stats."""
    from jax._src import test_util as jtu

    N = 8
    rng = np.random.default_rng(0)
    svc = AsyncSortService(max_batch=N, max_delay_ms=2000.0)
    # warmup: same bucket, same coalesced batch shape -> compiles (N, 1024)
    futs = [svc.submit_async(_mk(rng, 1000)) for _ in range(N)]
    for f in futs:
        f.result(timeout=120)
    batches_before = svc.stats.batches

    reqs = [_mk(rng, 900 + i) for i in range(N)]  # same 1024 bucket
    results = [None] * N

    def producer(i):
        results[i] = svc.submit_async(reqs[i]).result(timeout=120)

    with jtu.count_jit_and_pmap_lowerings() as count:
        threads = [threading.Thread(target=producer, args=(i,)) for i in range(N)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    assert count[0] == 0, "steady-state async path must not re-trace"
    executed = svc.stats.batches - batches_before
    assert executed < N, "cross-caller requests must coalesce"
    assert executed == 1  # max_batch == N and all arrive within max_delay
    for r, o in zip(reqs, results):
        assert (o == np.sort(r)).all()
    # QueueStats saw the coalesced batch
    st = svc.stats
    assert isinstance(st, QueueStats)
    assert st.coalesced_requests >= 2 * N and st.coalesced_batches >= 2
    assert st.batch_sizes[-1] == N and st.fill_ratio() > 0.9
    pct = st.latency_percentiles()
    assert 0 <= pct[50] <= pct[99]
    svc.close()


def test_many_threads_many_requests_correct_and_order_stable():
    """Stress: mixed kinds/buckets from many threads; every future resolves
    to its own request's oracle (no cross-request mixups under coalescing)."""
    rng = np.random.default_rng(1)
    svc = AsyncSortService(max_batch=16, max_delay_ms=5.0)
    per_thread = 6
    n_threads = 6
    payloads = [
        [_mk(np.random.default_rng(100 * t + j), 50 + 37 * (j % 4))
         for j in range(per_thread)]
        for t in range(n_threads)
    ]
    errors = []

    def producer(t):
        try:
            futs = []
            for j, r in enumerate(payloads[t]):
                if j % 3 == 0:
                    futs.append(("argsort", r, svc.submit_async(r, kind="argsort")))
                elif j % 3 == 1:
                    v = np.arange(len(r), dtype=np.int32)
                    futs.append(
                        ("sort_kv", r, svc.submit_async(r, kind="sort_kv", values=v))
                    )
                else:
                    futs.append(("sort", r, svc.submit_async(r)))
            for kind, r, f in futs:
                ref = np.argsort(r, kind="stable")
                if kind == "sort":
                    assert (f.result(timeout=120) == np.sort(r)).all()
                elif kind == "argsort":
                    assert (f.result(timeout=120) == ref).all()
                else:
                    sk, sv = f.result(timeout=120)
                    assert (sk == r[ref]).all() and (sv == ref).all()
        except Exception as e:  # pragma: no cover - surfaced via the assert below
            errors.append(e)

    threads = [threading.Thread(target=producer, args=(t,)) for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    assert svc.stats.requests == n_threads * per_thread
    assert svc.stats.coalesced_batches < n_threads * per_thread  # some merging
    svc.close()


# ----------------------------------------------------------- backpressure ---
def test_backpressure_reject_policy_raises_queue_full():
    svc = AsyncSortService(maxsize=2, on_full="reject", start=False)
    rng = np.random.default_rng(2)
    f1 = svc.submit_async(_mk(rng, 100))
    f2 = svc.submit_async(_mk(rng, 100))
    with pytest.raises(stdqueue.Full):
        svc.submit_async(_mk(rng, 100))
    assert svc.stats.rejected == 1 and svc.stats.enqueued == 2
    svc.start()  # dispatcher drains the two admitted requests
    assert f1.result(timeout=120) is not None
    assert f2.result(timeout=120) is not None
    svc.close()


def test_backpressure_block_policy_completes_everything():
    """maxsize=1 + blocking producers: submits stall instead of failing, and
    every request still resolves correctly."""
    rng = np.random.default_rng(3)
    svc = AsyncSortService(maxsize=1, on_full="block", max_batch=4, max_delay_ms=1.0)
    reqs = [_mk(rng, 200) for _ in range(12)]
    futs = [svc.submit_async(r) for r in reqs]
    for r, f in zip(reqs, futs):
        assert (f.result(timeout=120) == np.sort(r)).all()
    assert svc.stats.rejected == 0 and svc.stats.enqueued == 12
    svc.close()


# -------------------------------------------------------- drain and close ---
def test_drain_then_close_then_submit_raises():
    rng = np.random.default_rng(4)
    svc = AsyncSortService(max_batch=4, max_delay_ms=1.0)
    futs = [svc.submit_async(_mk(rng, 300)) for _ in range(6)]
    assert svc.drain(timeout=120)
    assert all(f.done() for f in futs)
    svc.close()
    svc.close()  # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit_async(_mk(rng, 10))


def test_close_resolves_backlog_of_never_started_service():
    """close() on a staged (start=False) service must not strand futures."""
    rng = np.random.default_rng(5)
    svc = AsyncSortService(start=False, max_batch=64, max_delay_ms=10_000.0)
    futs = [svc.submit_async(_mk(rng, 64)) for _ in range(3)]
    svc.close()  # starts, drains (flushing the half-empty batch), stops
    assert all(f.done() for f in futs)
    assert svc.stats.batch_sizes[-1] == 3  # flushed below max_batch on close


def test_context_manager_and_execution_error_propagates_to_futures():
    rng = np.random.default_rng(6)
    with AsyncSortService(max_batch=2, max_delay_ms=1.0) as svc:
        ok = svc.submit_async(_mk(rng, 50))
        assert len(ok.result(timeout=120)) == 50
        # inject an execution failure: every future in the batch must carry it
        boom = RuntimeError("injected")

        def exploding(*a, **k):
            raise boom

        svc.service._run_group = exploding
        bad = [svc.submit_async(_mk(rng, 50)) for _ in range(2)]
        for f in bad:
            assert f.exception(timeout=120) is boom
    with pytest.raises(RuntimeError):
        svc.submit_async(_mk(rng, 10))  # context exit closed it


def test_validation_errors_raise_synchronously():
    svc = AsyncSortService(start=False)
    with pytest.raises(ValueError, match="NaN"):
        svc.submit_async(np.array([1.0, np.nan], np.float32))
    with pytest.raises(ValueError):
        svc.submit_async(np.zeros((2, 2), np.int32))  # not 1-D
    with pytest.raises(ValueError):
        svc.submit_async(np.arange(4), kind="sort_kv")  # missing values
    with pytest.raises(ValueError):
        svc.submit_async(np.arange(4), kind="nope")
    assert svc.stats.enqueued == 0
    svc.close()


# ------------------------------------------------------- stats accounting ---
def test_elapsed_accounting_stays_meaningful_under_concurrent_submitters():
    """Regression for summed-overlapping-spans accounting: N threads hammering
    one SortService must report busy time <= real wall time (interval union),
    so throughput_keys_per_s stays a real keys/sec figure."""
    svc = SortService()
    rng = np.random.default_rng(7)
    reqs = [rng.integers(0, 1000, 2000).astype(np.int32) for _ in range(4)]
    svc.submit(reqs)  # warmup compile outside the timed window
    svc.stats.elapsed_s = 0.0

    N = 6
    t0 = time.perf_counter()

    def hammer():
        for _ in range(5):
            svc.submit(reqs)

    threads = [threading.Thread(target=hammer) for _ in range(N)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    assert 0 < svc.stats.elapsed_s <= wall * 1.05, (svc.stats.elapsed_s, wall)
    assert svc.stats.throughput_keys_per_s() > 0


def test_cancelled_future_is_skipped_without_killing_the_dispatcher():
    """Caller-side Future.cancel() on a queued request: the request is
    dropped, its batchmates still execute, and the dispatcher keeps serving."""
    rng = np.random.default_rng(8)
    svc = AsyncSortService(start=False, max_batch=4, max_delay_ms=1.0)
    r1, r2 = _mk(rng, 40), _mk(rng, 40)
    f1 = svc.submit_async(r1)
    f2 = svc.submit_async(r2)
    assert f1.cancel()
    svc.start()
    assert (f2.result(timeout=120) == np.sort(r2)).all()
    assert f1.cancelled()
    r3 = _mk(rng, 40)
    assert (svc.submit_async(r3).result(timeout=120) == np.sort(r3)).all()
    svc.close()


def test_caller_may_reuse_its_buffer_after_submit_async():
    """submit_async snapshots the request: mutating the caller's array while
    the request waits in the coalescing window must not corrupt the result."""
    rng = np.random.default_rng(9)
    svc = AsyncSortService(start=False, max_batch=8, max_delay_ms=1.0)
    buf = _mk(rng, 128)
    want = np.sort(buf)
    vbuf = np.arange(128, dtype=np.int32)
    ref = np.argsort(buf, kind="stable")
    f = svc.submit_async(buf)
    fkv = svc.submit_async(buf, kind="sort_kv", values=vbuf)
    buf[:] = -1  # caller reuses its buffer before the batch executes
    vbuf[:] = -1
    svc.start()
    assert (f.result(timeout=120) == want).all()
    sk, sv = fkv.result(timeout=120)
    assert (sv == ref).all()
    svc.close()
