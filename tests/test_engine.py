"""repro.engine: autotuned plans, compiled-plan cache, key-value sorting."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from conftest import run_with_devices
from repro.engine import (
    Planner,
    SortPlan,
    SortService,
    argsort,
    plan_key,
    size_bucket,
    sort_kv,
    sort_pairs,
    topk,
)

RNG = np.random.default_rng(0)


def _key_cases(n, rng):
    base = rng.integers(100, 1000, n).astype(np.int32)
    return {
        "random": base,
        "sorted": np.sort(base),
        "reverse": np.sort(base)[::-1].copy(),
        "duplicate_heavy": rng.integers(0, 7, n).astype(np.int32),
    }


# ----------------------------------------------------------------- planner ---
def test_autotune_selects_and_persists_plan(tmp_path):
    path = str(tmp_path / "plans.json")
    planner = Planner(path)
    plan = planner.autotune(3000, jnp.int32, quick=True, reps=1)
    assert plan.strategy == "shared"
    assert plan.us_per_call > 0
    # persisted: a fresh planner reloads the same plan, bucketed by pow2 size
    reloaded = Planner(path)
    assert reloaded.lookup(3000, jnp.int32) == plan
    assert reloaded.lookup(4096, jnp.int32) == plan  # same 4096 bucket
    assert reloaded.lookup(5000, jnp.int32) is None  # 8192 bucket untuned
    assert reloaded.plan_for(5000, jnp.int32).strategy == "shared"  # default rule


def test_plan_key_separates_dtype_and_bucket():
    assert plan_key(3000, jnp.int32) == plan_key(4096, jnp.int32)
    assert plan_key(3000, jnp.int32) != plan_key(3000, jnp.float32)
    assert plan_key(4096, jnp.int32) != plan_key(4097, jnp.int32)


def test_autotune_sweeps_pallas_and_roundtrips_block_n(tmp_path):
    """Acceptance: the full candidate sweep contains pallas plans with a
    block_n grid, and a tuned pallas plan survives the JSON round-trip."""
    from repro.engine.planner import PALLAS_BLOCK_SWEEP, candidate_plans

    cands = candidate_plans()
    pallas = [c for c in cands if c.local_impl == "pallas"]
    assert sorted(c.block_n for c in pallas) == sorted(PALLAS_BLOCK_SWEEP)
    assert [c for c in cands if c.local_impl == "xla"], "xla stays in the sweep"

    # an actual sweep on this container: small bucket keeps interpret mode cheap
    path = str(tmp_path / "plans.json")
    planner = Planner(path)
    plan = planner.autotune(200, jnp.int32, reps=1)
    assert plan.us_per_call > 0
    reloaded = Planner(path).lookup(200, jnp.int32)
    assert reloaded == plan
    assert reloaded.block_n == plan.block_n  # tuned block_n round-trips

    # a pallas winner (forced) round-trips its tile width exactly
    planner.plans[plan_key(8192, jnp.float32)] = SortPlan(
        "shared", local_impl="pallas", block_n=512
    )
    planner.save()
    got = Planner(path).lookup(8192, jnp.float32)
    assert got.local_impl == "pallas" and got.block_n == 512


def test_api_sort_pallas_local_impl_matches_numpy():
    """Acceptance: sort(x, strategy='shared', local_impl='pallas') == np.sort
    for non-pow2 and batched inputs (interpret mode on this container)."""
    from repro.core import sort

    rng = np.random.default_rng(11)
    x = rng.integers(-500, 500, 777).astype(np.int32)  # non-pow2
    got = sort(jnp.asarray(x), strategy="shared", local_impl="pallas", block_n=128)
    assert (np.asarray(got) == np.sort(x)).all()
    xb = rng.standard_normal((2, 3, 100)).astype(np.float32)  # batched
    got = sort(jnp.asarray(xb), strategy="shared", local_impl="pallas", block_n=64,
               n_threads=4)
    assert np.allclose(np.asarray(got), np.sort(xb, -1))
    got = sort(jnp.asarray(x), plan=SortPlan("shared", local_impl="pallas", block_n=128),
               ascending=False)
    assert (np.asarray(got) == np.sort(x)[::-1]).all()


def test_api_sort_honours_strategy_and_plan_overrides():
    from repro.core import sort

    x = jnp.asarray(RNG.integers(100, 1000, 2048).astype(np.int32))
    want = np.sort(np.asarray(x))
    for strategy in ("shared_merge", "shared_hybrid"):
        assert (np.asarray(sort(x, strategy=strategy)) == want).all()
    assert (np.asarray(sort(x, plan=SortPlan("shared", local_impl="bitonic"))) == want).all()
    assert (np.asarray(sort(x)) == want).all()  # planner default path
    with pytest.raises(ValueError):
        sort(x, strategy="nope")
    with pytest.raises(ValueError):
        sort(x, strategy="cluster")  # needs mesh= and axis=
    with pytest.raises(ValueError, match="ascending"):
        sort(x, strategy="cluster", ascending=False)  # cluster is ascending-only


# ------------------------------------------------------------------ kv API ---
def test_sort_kv_and_argsort_match_numpy_single_device():
    for name, k in _key_cases(2000, np.random.default_rng(1)).items():
        v = np.arange(len(k), dtype=np.int32)
        ref = np.argsort(k, kind="stable")
        sk, sv = sort_pairs(jnp.asarray(k), jnp.asarray(v))
        assert (np.asarray(sk) == k[ref]).all(), name
        assert (np.asarray(sv) == ref).all(), name
        assert (np.asarray(argsort(jnp.asarray(k))) == ref).all(), name
        # descending stable: ties keep original order
        refd = np.argsort(-k.astype(np.int64), kind="stable")
        assert (np.asarray(argsort(jnp.asarray(k), ascending=False)) == refd).all(), name


def test_sort_kv_pytree_and_batched():
    rng = np.random.default_rng(2)
    k = rng.standard_normal((3, 100)).astype(np.float32)
    v = {"a": rng.standard_normal((3, 100, 4)).astype(np.float32)}
    sk, sv = sort_kv(jnp.asarray(k), jax.tree.map(jnp.asarray, v))
    order = np.argsort(k, axis=-1, kind="stable")
    assert np.allclose(np.asarray(sk), np.take_along_axis(k, order, -1))
    assert np.allclose(
        np.asarray(sv["a"]),
        np.take_along_axis(v["a"], order[..., None], 1),
    )


def test_topk_matches_lax_top_k():
    x = RNG.standard_normal((5, 64)).astype(np.float32)
    x[:, 10] = x[:, 20]  # force ties
    vals, idx = topk(jnp.asarray(x), 8)
    lv, li = jax.lax.top_k(jnp.asarray(x), 8)
    assert np.allclose(np.asarray(vals), np.asarray(lv))
    assert (np.asarray(idx) == np.asarray(li)).all()


def test_kv_pallas_impl_matches_numpy_stable():
    """sort_kv / argsort / topk on the kernel path: exact np.argsort(stable)
    equivalence, non-pow2 and batched, both directions."""
    rng = np.random.default_rng(12)
    k = rng.integers(0, 7, 300).astype(np.int32)  # duplicate-heavy
    ref = np.argsort(k, kind="stable")
    assert (np.asarray(argsort(jnp.asarray(k), impl="pallas", block_n=64)) == ref).all()
    refd = np.argsort(~k, kind="stable")
    got = argsort(jnp.asarray(k), impl="pallas", block_n=64, ascending=False)
    assert (np.asarray(got) == refd).all()

    kb = rng.standard_normal((3, 100)).astype(np.float32)  # batched kv round-trip
    v = {"a": rng.standard_normal((3, 100, 2)).astype(np.float32)}
    sk, sv = sort_kv(jnp.asarray(kb), jax.tree.map(jnp.asarray, v),
                     impl="pallas", block_n=64)
    order = np.argsort(kb, axis=-1, kind="stable")
    assert np.allclose(np.asarray(sk), np.take_along_axis(kb, order, -1))
    assert np.allclose(np.asarray(sv["a"]),
                       np.take_along_axis(v["a"], order[..., None], 1))

    x = rng.standard_normal((2, 64)).astype(np.float32)
    x[:, 3] = x[:, 9]  # ties: stable descending == lax.top_k
    vals, idx = topk(jnp.asarray(x), 5, impl="pallas", block_n=64)
    lv, li = jax.lax.top_k(jnp.asarray(x), 5)
    assert np.allclose(np.asarray(vals), np.asarray(lv))
    assert (np.asarray(idx) == np.asarray(li)).all()


def test_sort_kv_argsort_cluster_matches_numpy_reference():
    """Acceptance: engine kv ops == np.argsort references on a multi-device
    CPU mesh, for random / sorted / reverse / duplicate-heavy inputs."""
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.engine import sort_kv, sort_pairs, argsort
        mesh = jax.make_mesh((8,), ("x",))
        rng = np.random.default_rng(0)
        n = 4096
        base = rng.integers(100, 1000, n).astype(np.int32)
        cases = {
            "random": base,
            "sorted": np.sort(base),
            "reverse": np.sort(base)[::-1].copy(),
            "duplicate_heavy": rng.integers(0, 7, n).astype(np.int32),
        }
        for name, k in cases.items():
            v = rng.standard_normal((n, 3)).astype(np.float32)
            ref = np.argsort(k, kind="stable")
            sk, sv = sort_pairs(jnp.asarray(k), jnp.asarray(v), mesh=mesh, axis="x")
            assert (np.asarray(sk) == k[ref]).all(), name
            assert (np.asarray(sv) == v[ref]).all(), name
            idx = argsort(jnp.asarray(k), mesh=mesh, axis="x")
            assert (np.asarray(idx) == ref).all(), name
            # descending must also be stable (ties keep arrival order)
            refd = np.argsort(~k, kind="stable")
            idxd = argsort(jnp.asarray(k), mesh=mesh, axis="x", ascending=False)
            assert (np.asarray(idxd) == refd).all(), name
        # pytree payload + int8 wire compression: float leaves quantized
        # (close), integer leaves must travel uncompressed (exact)
        k = cases["random"]
        vals = {"f": rng.standard_normal((n, 4)).astype(np.float32) * 3,
                "i": np.arange(n, dtype=np.int32)}
        ref = np.argsort(k, kind="stable")
        sk, sv = sort_kv(jnp.asarray(k), jax.tree.map(jnp.asarray, vals),
                         mesh=mesh, axis="x", compress=True)
        assert (np.asarray(sk) == k[ref]).all()
        assert (np.asarray(sv["i"]) == ref).all(), "int payloads must be exact"
        rel = np.abs(np.asarray(sv["f"]) - vals["f"][ref]).max() / np.abs(vals["f"]).max()
        assert rel < 0.02, rel
        print("cluster kv ok")
    """)


# ----------------------------------------------------------------- service ---
def test_service_zero_recompiles_for_same_bucket_traffic():
    """Acceptance: a second submit with same-bucket shapes performs zero new
    compilations — asserted with jax's lowering counter, not just ours."""
    from jax._src import test_util as jtu

    rng = np.random.default_rng(3)
    svc = SortService()
    first = [rng.integers(0, 1000, n).astype(np.int32) for n in (1000, 800, 500)]
    out = svc.submit(first)
    for r, o in zip(first, out):
        assert (o == np.sort(r)).all()
    compiles_after_first = svc.cache.misses
    assert compiles_after_first == 2  # one executable per (1024, 512) bucket

    second = [rng.integers(0, 1000, n).astype(np.int32) for n in (900, 700, 400)]
    with jtu.count_jit_and_pmap_lowerings() as count:
        out2 = svc.submit(second)
    assert count[0] == 0, "serving hot path must not re-trace"
    assert svc.cache.misses == compiles_after_first
    for r, o in zip(second, out2):
        assert (o == np.sort(r)).all()
    assert svc.stats.requests == 6 and svc.stats.throughput_keys_per_s() > 0


def test_service_kinds_and_stats():
    rng = np.random.default_rng(4)
    svc = SortService()
    reqs = [rng.integers(0, 100, n).astype(np.int32) for n in (300, 200)]
    vals = [rng.standard_normal((len(r), 2)).astype(np.float32) for r in reqs]
    for r, o in zip(reqs, svc.submit(reqs, kind="argsort")):
        assert (o == np.argsort(r, kind="stable")).all()
    for r, o in zip(reqs, svc.submit(reqs, kind="sort", ascending=False)):
        assert (o == np.sort(r)[::-1]).all()
    for r, v, (sk, sv) in zip(reqs, vals, svc.submit(reqs, kind="sort_kv", values=vals)):
        ref = np.argsort(r, kind="stable")
        assert (sk == r[ref]).all() and (sv == v[ref]).all()
    assert svc.stats.batches >= 3
    with pytest.raises(ValueError):
        svc.submit(reqs, kind="sort_kv")  # missing values
    with pytest.raises(ValueError):
        svc.submit([np.zeros((2, 2), np.int32)])  # not 1-D
    with pytest.raises(ValueError, match="NaN"):
        svc.submit([np.array([1.0, np.nan], np.float32)])


def test_service_sort_kv_mixed_value_shapes_same_bucket():
    """Requests whose keys share a length bucket but carry different payload
    shapes must group separately, not error."""
    rng = np.random.default_rng(5)
    svc = SortService()
    reqs = [rng.integers(0, 100, n).astype(np.int32) for n in (900, 1000)]
    vals = [
        rng.standard_normal((900, 2)).astype(np.float32),
        rng.standard_normal((1000, 4)).astype(np.float32),
    ]
    for r, v, (sk, sv) in zip(reqs, vals, svc.submit(reqs, kind="sort_kv", values=vals)):
        ref = np.argsort(r, kind="stable")
        assert (sk == r[ref]).all() and (sv == v[ref]).all()


def test_service_runs_tuned_pallas_plan_and_keys_on_block_n():
    """A planner cell tuned to pallas drives the service's local sort; two
    plans differing only in block_n must compile distinct executables."""
    rng = np.random.default_rng(6)
    planner = Planner()
    planner.plans[plan_key(512, jnp.int32)] = SortPlan(
        "shared", local_impl="pallas", block_n=64
    )
    svc = SortService(planner=planner)
    reqs = [rng.integers(0, 1000, n).astype(np.int32) for n in (500, 400)]
    for r, o in zip(reqs, svc.submit(reqs)):
        assert (o == np.sort(r)).all()
    entries_before = len(svc.cache.executables)

    planner.plans[plan_key(512, jnp.int32)] = SortPlan(
        "shared", local_impl="pallas", block_n=128
    )
    for r, o in zip(reqs, svc.submit(reqs)):
        assert (o == np.sort(r)).all()
    assert len(svc.cache.executables) == entries_before + 1, (
        "block_n must be part of the executable cache key"
    )


def test_size_bucket_pow2():
    assert size_bucket(1000) == 1024
    assert size_bucket(1024) == 1024
    assert size_bucket(3, min_bucket=8) == 8


# ------------------------------------------------------ plan-cache robustness ---
def test_planner_load_graceful_on_corrupt_or_unknown_cache(tmp_path):
    """A serving process must never die because its tuned-plans file rotted:
    corrupt/truncated/unknown-schema caches warn and fall back to the
    default-plan rule instead of raising."""
    import json
    import warnings

    bad_files = {
        "corrupt.json": "{this is not json",
        "truncated.json": '{"version": 1, "plans": {"4096|int32|x": {"strat',
        "badversion.json": '{"version": 99, "plans": {}}',
        "notadict.json": '{"version": 1, "plans": {"k": ["not", "a", "dict"]}}',
        "badstrategy.json": '{"version": 1, "plans": {"k": {"strategy": "warp"}}}',
        "noplans.json": '{"version": 1}',
        "plansnotobj.json": '{"version": 1, "plans": 7}',
    }
    for name, content in bad_files.items():
        p = tmp_path / name
        p.write_text(content)
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            planner = Planner(str(p))
        assert planner.plans == {}, name
        assert any("plan cache" in str(x.message) for x in w), name
        # lookups fall back to the default rule, not an exception
        assert planner.plan_for(1000, jnp.int32).strategy == "shared", name

    # unknown *extra fields* in an otherwise valid entry are forward-compat:
    # the known fields load, the unknown ones are ignored
    fwd = tmp_path / "forward.json"
    fwd.write_text(json.dumps({
        "version": 1,
        "plans": {plan_key(4096, jnp.int32): {
            "strategy": "shared", "local_impl": "xla", "from_the_future": 1,
        }},
    }))
    assert Planner(str(fwd)).lookup(4096, jnp.int32).local_impl == "xla"

    # a live re-load of a rotted file keeps the last-known-good plans
    # instead of wiping the table a serving process is already using
    survivor = Planner(str(fwd))
    assert survivor.plans
    with warnings.catch_warnings(record=True):
        warnings.simplefilter("always")
        survivor.load(str(tmp_path / "corrupt.json"))
    assert survivor.lookup(4096, jnp.int32).local_impl == "xla"

    # tooling that *writes* plan caches wants the error, not the fallback
    with pytest.raises(Exception):
        Planner().load(str(tmp_path / "corrupt.json"), strict=True)
