"""Sharding rules: every param leaf of every arch gets a valid spec; fit_spec
degrades gracefully; radix partitioners keep the bucket->shard map ordered."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare container — requirements-dev.txt installs the real one
    from _hypothesis_shim import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs.base import ARCHS
from repro.core.radix import decimal_msd_bucket, range_bucket, splitter_bucket
from repro.distributed.sharding import fit_spec, param_specs
from repro.models.transformer import model_init

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_every_param_leaf_gets_a_spec(arch):
    cfg = ARCHS[arch]
    shapes = jax.eval_shape(
        lambda k: model_init(k, cfg, ep_shards=16), jax.random.PRNGKey(0)
    )
    specs = param_specs(shapes)
    flat_s, _ = jax.tree_util.tree_flatten(specs, is_leaf=lambda x: isinstance(x, P))
    flat_l = jax.tree_util.tree_flatten(shapes)[0]
    assert len(flat_s) == len(flat_l)
    for spec, leaf in zip(flat_s, flat_l):
        assert isinstance(spec, P)
        assert len(spec) == leaf.ndim, (spec, leaf.shape)
        # big weights must be sharded on at least one axis (routers are the
        # largest intentionally-replicated leaves, a few M params)
        if leaf.size > 16_000_000:
            assert any(a is not None for a in spec), (spec, leaf.shape)


def test_fit_spec_drops_non_dividing_axes():
    mesh = jax.make_mesh((1,), ("data",))  # sizes: data=1
    # fabricate a mesh-like with shape dict for the pure function
    class M:
        axis_names = ("pod", "data")
        shape = {"pod": 2, "data": 16}

    assert fit_spec((1, 5), P(("pod", "data"), None), M()) == P(None, None)
    assert fit_spec((32, 5), P(("pod", "data"), None), M()) == P(("pod", "data"), None)
    assert fit_spec((2, 5), P(("pod", "data"), None), M()) == P("pod", None)
    assert fit_spec((16, 5), P("data", "pod"), M()) == P("data", None)


ints = st.lists(st.integers(0, 999), min_size=1, max_size=200)


@given(ints)
def test_decimal_bucket_is_msd(xs):
    x = jnp.asarray(np.asarray(xs, np.int32))
    b = np.asarray(decimal_msd_bucket(x, digits=3))
    assert ((b == np.clip(np.asarray(xs) // 100, 0, 9))).all()


@given(ints, st.integers(1, 4))
def test_range_bucket_monotone(xs, log_b):
    """Bucket ids are monotone in the key — the property that makes the
    contiguous bucket->shard map preserve global sorted order."""
    nb = 1 << log_b
    x = np.sort(np.asarray(xs, np.int32))
    b = np.asarray(range_bucket(jnp.asarray(x), n_buckets=nb, lo=0, hi=1000))
    assert (np.diff(b) >= 0).all()
    assert b.min() >= 0 and b.max() < nb


@given(ints)
def test_splitter_bucket_monotone_and_balancedish(xs):
    x = np.asarray(xs, np.int32)
    spl = np.quantile(x, [0.25, 0.5, 0.75]).astype(np.int32)
    spl = np.sort(spl)
    b = np.asarray(splitter_bucket(jnp.asarray(np.sort(x)), jnp.asarray(spl)))
    assert (np.diff(b) >= 0).all()
    assert b.max() <= 3
