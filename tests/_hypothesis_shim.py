"""Minimal stand-in for ``hypothesis`` when it isn't installed.

Covers exactly the subset the test suite uses — ``given``, ``settings``
profiles, and the ``integers`` / ``floats`` / ``lists`` strategies — by
drawing a fixed-seed pseudo-random example set per test (first example is
the minimal one, so size/empty edge cases are always exercised).  With
``hypothesis`` installed (see requirements-dev.txt) the real library is
used instead; this shim only keeps collection green in bare containers.
"""
from __future__ import annotations

import functools

import numpy as np


class _Strategy:
    def __init__(self, draw, minimal):
        self._draw = draw
        self._minimal = minimal

    def draw(self, rng):
        return self._draw(rng)

    def minimal(self):
        return self._minimal()


class strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)),
            lambda: int(min_value),
        )

    @staticmethod
    def floats(min_value, max_value, allow_nan=False, width=64):
        cast = np.float32 if width == 32 else np.float64
        return _Strategy(
            lambda rng: float(cast(rng.uniform(min_value, max_value))),
            lambda: float(cast(min_value)),
        )

    @staticmethod
    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(
            lambda rng: elements[int(rng.integers(0, len(elements)))],
            lambda: elements[0],
        )

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(0, 2)), lambda: False)

    @staticmethod
    def lists(elements, *, min_size=0, max_size=10):
        return _Strategy(
            lambda rng: [
                elements.draw(rng)
                for _ in range(int(rng.integers(min_size, max_size + 1)))
            ],
            lambda: [elements.minimal() for _ in range(min_size)],
        )


class settings:
    _profiles = {"default": {"max_examples": 25}}
    _active = "default"

    @classmethod
    def register_profile(cls, name, **kwargs):
        cls._profiles[name] = kwargs

    @classmethod
    def load_profile(cls, name):
        cls._active = name


def given(*strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = settings._profiles[settings._active].get("max_examples", 25)
            rng = np.random.default_rng(1234)
            fn(*args, *[s.minimal() for s in strats], **kwargs)
            for _ in range(max(0, n - 1)):
                fn(*args, *[s.draw(rng) for s in strats], **kwargs)

        # pytest must not see the drawn params as fixtures via __wrapped__
        del wrapper.__wrapped__
        return wrapper

    return deco
