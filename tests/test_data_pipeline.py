"""Data pipeline: determinism, prefetch, length bucketing via the paper sort."""
import numpy as np

from repro.data.pipeline import Prefetcher, SyntheticLM, length_bucketed_batches


def test_deterministic_given_seed():
    a = next(iter(SyntheticLM(vocab=50, batch=2, seq=8, seed=7)))
    b = next(iter(SyntheticLM(vocab=50, batch=2, seq=8, seed=7)))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = next(iter(SyntheticLM(vocab=50, batch=2, seq=8, seed=8)))
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_labels_are_shifted_tokens():
    b = next(iter(SyntheticLM(vocab=50, batch=2, seq=8, seed=0)))
    assert b["tokens"].shape == (2, 8)
    assert b["labels"].shape == (2, 8)


def test_prefetcher_preserves_order():
    pipe = SyntheticLM(vocab=50, batch=1, seq=4, seed=1)
    direct = [next(iter(pipe)) for _ in range(3)]
    pipe2 = SyntheticLM(vocab=50, batch=1, seq=4, seed=1)
    pre = Prefetcher(iter(pipe2), depth=2)
    fetched = [next(pre) for _ in range(3)]
    pre.close()
    for d, f in zip(direct, fetched):
        np.testing.assert_array_equal(d["tokens"], f["tokens"])


def test_length_bucketing_reduces_padding_waste():
    rng = np.random.default_rng(0)
    lengths = rng.integers(10, 2048, size=512)
    batches, before, after = length_bucketed_batches(lengths, batch=16)
    assert after < before * 0.25, (before, after)
    # batches form a permutation of the usable prefix
    assert sorted(batches.reshape(-1).tolist()) == list(range(512))
