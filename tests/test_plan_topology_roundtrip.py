"""Warm-start across topologies: ``/procs<P>x<D>`` cells through the cache.

The distributed autotune sweep has rank 0 persist cells keyed by the
multi-process topology fingerprint.  A later process — another rank of the
same topology, a tooling script, or a single-process ``serve.py`` — must
read those cells back exactly, and a single-process server must never
mistake them for its own ``local/...`` cells (the plans were timed over
collectives that cross real process boundaries).
"""
import json

import jax.numpy as jnp

from repro.engine.adapt import LearnedCapacity
from repro.engine.planner import (
    Planner,
    SortPlan,
    mesh_fingerprint,
    parse_plan_key,
    plan_key,
)

PROCS_FP = "cpu/x=4/procs2x2"


def _procs_cell():
    return plan_key(4096, jnp.int32, fingerprint=PROCS_FP)


def _tuned_plan():
    return SortPlan(
        "cluster", local_impl="xla", capacity_factor=2.0,
        mode="sample", us_per_call=123.45,
    )


def test_procs_cells_round_trip_bit_stably(tmp_path):
    """What rank 0 saves, a fresh single-process planner loads back exactly
    — and a re-save is byte-identical (the cache is a fixed point)."""
    path = str(tmp_path / "plans.json")
    key = _procs_cell()
    p = Planner(path)
    p.plans[key] = _tuned_plan()
    p.learned[key] = LearnedCapacity(
        2.5, 3.0, 7, partition="sample", skew_strikes=3, demotions=1
    )
    p.save()
    with open(path, "rb") as f:
        first_bytes = f.read()

    fresh = Planner(path)
    assert fresh.plans[key] == _tuned_plan()
    assert fresh.learned[key] == p.learned[key]
    fresh.save()
    with open(path, "rb") as f:
        assert f.read() == first_bytes, "reload+save must be a fixed point"


def test_procs_cells_survive_strict_load_and_keep_schema_v3(tmp_path):
    path = str(tmp_path / "plans.json")
    p = Planner(path)
    p.plans[_procs_cell()] = _tuned_plan()
    p.save()
    with open(path) as f:
        doc = json.load(f)
    assert doc["version"] == 3, "procs cells are additive within schema v3"
    loaded = Planner().load(path, strict=True)
    assert set(loaded.plans) == {_procs_cell()}


def test_single_process_serve_does_not_warm_foreign_topology_cells(tmp_path):
    """A single-process server loading a cache written on a 2x2-process
    topology must not enumerate those cells for AOT warmup — their plans
    were timed over cross-process collectives it cannot reproduce — while
    its own local cells still warm."""
    path = str(tmp_path / "plans.json")
    p = Planner(path)
    p.plans[_procs_cell()] = _tuned_plan()
    local_key = plan_key(1024, jnp.int32)          # this process's own cell
    p.plans[local_key] = SortPlan("shared")
    p.save()

    server = Planner(path)
    assert server.warmup_cells() == [(1024, "int32")]
    # the foreign cell is still present and addressable, just not warmed
    assert _procs_cell() in server.plans


def test_explicit_fingerprint_lookup_reads_rank0_cells(tmp_path):
    """Tooling (or a coordinator inspecting a multi-host file) reaches the
    procs cells via ``plan_key(..., fingerprint=)`` without being part of
    the topology — and the parse round-trips the fingerprint."""
    path = str(tmp_path / "plans.json")
    p = Planner(path)
    p.plans[_procs_cell()] = _tuned_plan()
    p.learned[_procs_cell()] = LearnedCapacity(3.0, 3.0, 5)
    p.save()

    reader = Planner(path)
    key = plan_key(4096, jnp.int32, fingerprint=PROCS_FP)
    assert reader.plans[key].us_per_call == 123.45
    assert reader.capacity_factor_for(key) == 3.0
    bucket, dtype_name, fp = parse_plan_key(key)
    assert (bucket, dtype_name, fp) == (4096, "int32", PROCS_FP)


def test_current_process_fingerprint_is_single_process():
    """This pytest process is single-process jax: its fingerprint must carry
    no procs suffix, which is exactly why foreign procs cells never match."""
    assert "/procs" not in mesh_fingerprint()
    assert "/procs" not in mesh_fingerprint(None)
