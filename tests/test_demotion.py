"""Probation / demotion: the slow counter that un-latches skew promotion.

Promotion (radix -> sample) used to be one-way: a single skew era pinned a
cell on the balanced-but-slower sample partition forever.  These tests pin
the way back — a long calm streak demotes the cell — and, critically, that
demotion cannot *flap* under the concurrent-writer merge latch: the
``demotions`` generation counter makes a demoted cell win merges against
every stale promoted entry a laggard writer might re-save.
"""
import jax.numpy as jnp

from repro.engine.adapt import CapacityLearner, ExchangeObservation
from repro.engine.planner import Planner, plan_key

KEY = "4096|int32|cpu/x=8"


def _skewed_radix():
    # peak/mean ratio = 64 * 8 / 128 = 4.0 > promote_ratio
    return ExchangeObservation(
        m=128, part_buckets=8, capacity=64, peak=64,
        overflowed=True, retries=1, partition="radix",
    )


def _calm_sample():
    # ratio = 16 * 8 / 128 = 1.0 <= demote_ratio, no overflow
    return ExchangeObservation(
        m=128, part_buckets=8, capacity=32, peak=16,
        overflowed=False, retries=0, partition="sample",
    )


def _rough_sample():
    # ratio 3.0 > demote_ratio and overflowed: not evidence of calm
    return ExchangeObservation(
        m=128, part_buckets=8, capacity=32, peak=48,
        overflowed=True, retries=1, partition="sample",
    )


def _promoted_planner(path=None, *, demote_after=4):
    """A planner whose KEY cell has just latched to the sample partition."""
    p = Planner(path)
    p.learner = CapacityLearner(demote_after=demote_after)
    for _ in range(p.learner.promote_after):
        p.observe_exchange(KEY, _skewed_radix())
    assert p.promotion_state(KEY)[0] == "sample"
    return p


# ----------------------------------------------------------- the slow path ---
def test_calm_streak_demotes_after_threshold():
    p = _promoted_planner()
    for i in range(p.learner.demote_after - 1):
        entry = p.observe_exchange(KEY, _calm_sample())
        assert entry.partition == "sample", f"demoted early at streak {i + 1}"
        assert entry.calm_streak == i + 1
    entry = p.observe_exchange(KEY, _calm_sample())  # streak hits the bar
    assert entry.partition is None, "cell must demote back to the radix family"
    assert entry.demotions == 1
    assert entry.skew_strikes == 0 and entry.calm_streak == 0
    # the serving path follows: no more injected sample mode
    assert p.promotion_state(KEY) == (None, 0)


def test_rough_sample_call_resets_probation():
    p = _promoted_planner()
    for _ in range(p.learner.demote_after - 1):
        p.observe_exchange(KEY, _calm_sample())
    p.observe_exchange(KEY, _rough_sample())  # skew is back: streak resets
    for _ in range(p.learner.demote_after - 1):
        entry = p.observe_exchange(KEY, _calm_sample())
    assert entry.partition == "sample", "reset streak must restart from zero"
    assert entry.calm_streak == p.learner.demote_after - 1


def test_non_sample_observations_leave_probation_untouched():
    p = _promoted_planner()
    for _ in range(2):
        p.observe_exchange(KEY, _calm_sample())
    # untagged (e.g. MoE) and empty observations say nothing about calm
    untagged = ExchangeObservation(
        m=128, part_buckets=8, capacity=32, peak=16,
        overflowed=False, retries=0,
    )
    empty = ExchangeObservation(
        m=0, part_buckets=8, capacity=1, peak=0,
        overflowed=False, retries=0, partition="sample",
    )
    p.observe_exchange(KEY, untagged)
    entry = p.observe_exchange(KEY, empty)
    assert entry.partition == "sample" and entry.calm_streak == 2


def test_repromotion_backoff_doubles_the_threshold():
    p = _promoted_planner()
    for _ in range(p.learner.demote_after):
        p.observe_exchange(KEY, _calm_sample())
    assert p.learned[KEY].demotions == 1
    # the skew comes back: the ordinary three-strike promotion re-latches,
    # one generation up
    for _ in range(p.learner.promote_after):
        entry = p.observe_exchange(KEY, _skewed_radix())
    assert entry.partition == "sample" and entry.demotions == 1
    # this generation's probation is twice as long
    for _ in range(p.learner.demote_after):
        entry = p.observe_exchange(KEY, _calm_sample())
    assert entry.partition == "sample", "backoff must slow the second demotion"
    for _ in range(p.learner.demote_after):
        entry = p.observe_exchange(KEY, _calm_sample())
    assert entry.partition is None and entry.demotions == 2


# ------------------------------------------- no flapping under the merge ---
def test_demotion_survives_stale_promoted_writer(tmp_path):
    """The concurrent-writer no-flap guarantee: a laggard planner re-saving
    its stale promoted entry cannot resurrect a promotion the calm streak
    already demoted — in either save order."""
    for flip in (False, True):
        path = str(tmp_path / f"plans-{flip}.json")
        p1 = _promoted_planner(path)
        p1.save()
        p2 = Planner(path)  # loads the promoted entry; never sees the calm
        assert p2.promotion_state(KEY)[0] == "sample"

        for _ in range(p1.learner.demote_after):
            p1.observe_exchange(KEY, _calm_sample())
        assert p1.learned[KEY].partition is None

        first, second = (p2, p1) if flip else (p1, p2)
        first.save()
        second.save()
        fresh = Planner(path)
        got = fresh.learned[KEY]
        assert got.partition is None, f"stale promotion flapped back (flip={flip})"
        assert got.demotions == 1


def test_stale_writer_with_more_observations_still_cannot_flap(tmp_path):
    """Even when the stale promoted lineage is *more informed* (it wins the
    capacity factor), the partition decision follows the demotion
    generation, not the observation count."""
    path = str(tmp_path / "plans.json")
    p1 = _promoted_planner(path)
    p1.save()
    p2 = Planner(path)
    # p2 keeps serving skewed sample-era traffic: many more observations,
    # still generation 0
    p2.learner = CapacityLearner()
    for _ in range(3 * p2.learner.demote_after):
        p2.observe_exchange(KEY, _rough_sample())
    # p1 sees the calm era and demotes
    for _ in range(p1.learner.demote_after):
        p1.observe_exchange(KEY, _calm_sample())
    p1.save()
    p2.save()
    got = Planner(path).learned[KEY]
    assert got.observations == 3 * p2.learner.demote_after + 3
    assert got.partition is None and got.demotions == 1


def test_cluster_kwargs_stops_injecting_sample_mode_after_demotion():
    """The serving path end to end: a promoted cell's cluster_kwargs inject
    ``mode="sample"``; after the calm streak demotes it they stop, and the
    radix-family default is back in charge."""
    n, dtype = 4096, jnp.int32
    p = Planner()
    p.learner = CapacityLearner(demote_after=4)
    key = plan_key(n, dtype)
    for _ in range(p.learner.promote_after):
        p.observe_exchange(key, _skewed_radix())
    assert p.cluster_kwargs(n, dtype)["mode"] == "sample"
    for _ in range(p.learner.demote_after):
        p.observe_exchange(key, _calm_sample())
    assert "mode" not in p.cluster_kwargs(n, dtype)
