"""Multi-device tests (8 host devices via subprocess — keeps the main test
process at 1 device, per the dry-run isolation rule)."""
from conftest import run_with_devices


def test_distributed_merge_sort_model_c():
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import distributed_merge_sort
        mesh = jax.make_mesh((8,), ("x",))
        rng = np.random.default_rng(0)
        for n in [64, 4096]:
            x = rng.integers(100, 999, size=(n,)).astype(np.int32)
            out = np.asarray(distributed_merge_sort(jnp.asarray(x), mesh, "x"))
            assert (out == np.sort(x)).all(), n
        print("C ok")
    """)


def test_cluster_sort_model_d_modes():
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import cluster_sort
        mesh = jax.make_mesh((8,), ("x",))
        rng = np.random.default_rng(1)
        def check(x, **kw):
            slab, valid = cluster_sort(jnp.asarray(x), mesh, "x", **kw)
            got = np.asarray(slab)[np.asarray(valid)]
            assert (got == np.sort(x)).all(), kw
        x = rng.integers(100, 999, size=(8000,)).astype(np.int32)
        check(x, mode="range", lo=100, hi=1000, capacity_factor=1.5)
        check(x, mode="splitters", capacity_factor=1.5)
        check(x, mode="decimal", digits=3, capacity_factor=2.0)
        xs = (rng.zipf(1.5, size=8000) % 900 + 100).astype(np.int32)
        check(xs, mode="splitters", capacity_factor=1.5)   # balanced under skew
        check(xs, mode="range", lo=100, hi=1000, capacity_factor=1.2)  # retry path
        print("D ok")
    """)


def test_partition_combine_roundtrip():
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.core import partition_exchange, combine_exchange
        mesh = jax.make_mesh((8,), ("x",))
        rng = np.random.default_rng(2)
        def body(k, v):
            dest = (k % 8).astype(jnp.int32)
            ex = partition_exchange(k, v, dest, "x", capacity=k.shape[0])
            return combine_exchange(ex.recv_values, ex, "x")
        k = rng.integers(0, 1000, size=(800,)).astype(np.int32)
        v = rng.standard_normal((800, 4)).astype(np.float32)
        out = jax.jit(jax.shard_map(body, mesh=mesh,
            in_specs=(P("x"), P("x")), out_specs=P("x")))(jnp.asarray(k), jnp.asarray(v))
        assert np.allclose(np.asarray(out), v)
        print("roundtrip ok")
    """)


def test_bucketed_exchange_grouping():
    """n_buckets > shards: slab layout groups entries per local bucket."""
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.core import partition_exchange
        mesh = jax.make_mesh((4,), ("x",))
        rng = np.random.default_rng(3)
        B, C = 8, 50   # 2 buckets per shard
        def body(k):
            ex = partition_exchange(k, None, k % B, "x", capacity=C, n_buckets=B)
            return ex.recv_keys.reshape(1, -1), ex.counts[None], ex.overflow[None]
        k = rng.integers(0, 1000, size=(400,)).astype(np.int32)
        recv, counts, ovf = jax.jit(jax.shard_map(body, mesh=mesh,
            in_specs=P("x"), out_specs=(P("x"), P("x"), P("x"))))(jnp.asarray(k))
        assert not ovf.any()
        recv = np.asarray(recv).reshape(4, 4, 2, C)  # (me, sender, local_bkt, C)
        kk = np.asarray(k).reshape(4, 100)
        sent = np.iinfo(np.int32).max
        for me in range(4):
            for src in range(4):
                for lb in range(2):
                    bucket = me * 2 + lb
                    want = kk[src][kk[src] % B == bucket]
                    got = recv[me, src, lb]
                    got = got[got != sent]
                    assert (np.sort(got) == np.sort(want)).all()
        print("bucketed ok")
    """)


def test_cluster_decimal_bucket_rounding_and_capacity():
    """Model-D regression: decimal mode has 10 buckets, which must be rounded
    up to a multiple of the axis size for the exchange, with capacity sized
    per *bucket* (not per shard)."""
    from repro.core.cluster_sort import slab_geometry

    for P_ in (1, 2, 3, 4, 7, 8, 16):
        part, B, cap = slab_geometry("decimal", 1000, P_, 2.0)
        assert part == 10 and B >= 10 and B % P_ == 0, P_
        assert cap == 200  # ceil(2.0 * 1000 / 10) — per bucket
    assert slab_geometry("splitters", 1000, 8, 1.5) == (8, 8, 188)

    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import cluster_sort
        mesh = jax.make_mesh((8,), ("x",))   # 8 does not divide 10
        rng = np.random.default_rng(7)
        x = rng.integers(100, 1000, size=8000).astype(np.int32)
        slab, valid = cluster_sort(jnp.asarray(x), mesh, "x", mode="decimal",
                                   digits=3, capacity_factor=1.2)
        got = np.asarray(slab)[np.asarray(valid)]
        assert (got == np.sort(x)).all()
        print("decimal rounding ok")
    """)


def test_moe_training_on_mesh():
    """End-to-end: 2x4 mesh (data x model), MoE model trains, loss decreases."""
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp, functools
        from repro.models.transformer import ModelConfig, model_init, ShardCtx
        from repro.train.steps import train_step
        from repro.optim.adamw import OptConfig, init_opt_state
        from repro.distributed.sharding import param_specs, opt_state_specs, to_named
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        ctx = ShardCtx(mesh=mesh, axes=("data", "model"), ep_axis="model")
        cfg = ModelConfig("m", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                          head_dim=8, d_ff=16, vocab_size=64, pattern=("attn",),
                          ffn_pattern=("moe",), n_experts=4, top_k=2,
                          capacity_factor=4.0, param_dtype=jnp.float32,
                          compute_dtype=jnp.float32, kv_chunk=8)
        params = model_init(jax.random.PRNGKey(0), cfg, ep_shards=4)
        ocfg = OptConfig(peak_lr=5e-3, warmup_steps=3, total_steps=40)
        opt = init_opt_state(params, ocfg)
        params = jax.device_put(params, to_named(param_specs(params), mesh, like=params))
        step = jax.jit(functools.partial(train_step, cfg=cfg, opt_cfg=ocfg, ctx=ctx,
                                         loss_chunk=16))
        rng = np.random.default_rng(0)
        losses = []
        for i in range(25):
            toks = (rng.integers(0, 32, size=(8, 17)) * 2).astype(np.int32) % 64
            batch = {"tokens": jnp.asarray(toks[:, :-1]), "labels": jnp.asarray(toks[:, 1:])}
            params, opt, m = step(params, opt, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 1.0, (losses[0], losses[-1])
        print("mesh moe train ok", losses[0], "->", losses[-1])
    """)


def test_single_vs_mesh_forward_equivalence():
    """The sharded MoE forward must equal the single-device forward."""
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.models.transformer import ModelConfig, model_init, forward, ShardCtx
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = ModelConfig("m", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                          head_dim=8, d_ff=16, vocab_size=64, pattern=("attn",),
                          ffn_pattern=("moe",), n_experts=4, top_k=2,
                          capacity_factor=8.0, param_dtype=jnp.float32,
                          compute_dtype=jnp.float32, kv_chunk=8)
        params = model_init(jax.random.PRNGKey(0), cfg, ep_shards=4)
        toks = jnp.asarray(np.random.default_rng(1).integers(0, 64, (8, 16)), jnp.int32)
        ref, _ = forward(params, cfg, toks, remat=False)  # ctx=None single-device
        ctx = ShardCtx(mesh=mesh, axes=("data", "model"), ep_axis="model")
        got, _ = jax.jit(lambda p, t: forward(p, cfg, t, ctx=ctx, remat=False))(params, toks)
        err = np.abs(np.asarray(ref) - np.asarray(got)).max()
        assert err < 2e-2, err
        print("equivalence ok", err)
    """)


def test_compressed_dispatch_numerics_and_training():
    """int8-on-the-wire MoE dispatch: close forward, converging training."""
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp, functools
        from repro.models.transformer import ModelConfig, model_init, forward, ShardCtx
        from repro.train.steps import train_step
        from repro.optim.adamw import OptConfig, init_opt_state
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        ctx = ShardCtx(mesh=mesh, axes=("data", "model"), ep_axis="model")
        base = dict(n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
                    d_ff=16, vocab_size=64, pattern=("attn",), ffn_pattern=("moe",),
                    n_experts=4, top_k=2, capacity_factor=8.0,
                    param_dtype=jnp.float32, compute_dtype=jnp.float32, kv_chunk=8)
        cfg_f = ModelConfig("f", **base)
        cfg_q = ModelConfig("q", **base, compress_dispatch=True)
        params = model_init(jax.random.PRNGKey(0), cfg_f, ep_shards=4)
        toks = jnp.asarray(np.random.default_rng(1).integers(0, 64, (8, 16)), jnp.int32)
        yf, _ = jax.jit(lambda p, t: forward(p, cfg_f, t, ctx=ctx, remat=False))(params, toks)
        yq, _ = jax.jit(lambda p, t: forward(p, cfg_q, t, ctx=ctx, remat=False))(params, toks)
        rel = float(jnp.abs(yf - yq).max() / jnp.abs(yf).max())
        assert rel < 0.05, rel
        ocfg = OptConfig(peak_lr=5e-3, warmup_steps=3, total_steps=40)
        opt = init_opt_state(params, ocfg)
        step = jax.jit(functools.partial(train_step, cfg=cfg_q, opt_cfg=ocfg,
                                         ctx=ctx, loss_chunk=16))
        rng = np.random.default_rng(0)
        losses = []
        for i in range(15):
            t = (rng.integers(0, 32, size=(8, 17)) * 2).astype(np.int32) % 64
            batch = {"tokens": jnp.asarray(t[:, :-1]), "labels": jnp.asarray(t[:, 1:])}
            params, opt, m = step(params, opt, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] - 1.0, losses
        print("compressed dispatch ok", rel)
    """)


def test_elastic_rescale_checkpoint():
    """Save on 1 device -> restore + train on an 8-device mesh (elastic path)."""
    import tempfile

    with tempfile.TemporaryDirectory() as ckdir:
        # phase 1: single device, save
        run_with_devices(f"""
            import jax, jax.numpy as jnp, numpy as np, functools
            from repro.models.transformer import ModelConfig, model_init
            from repro.optim.adamw import OptConfig, init_opt_state
            from repro.train.steps import train_step
            from repro.checkpoint.manager import CheckpointManager
            cfg = ModelConfig("e", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                              head_dim=8, d_ff=16, vocab_size=64, pattern=("attn",),
                              ffn_pattern=("moe",), n_experts=4, top_k=2,
                              capacity_factor=8.0, param_dtype=jnp.float32,
                              compute_dtype=jnp.float32, kv_chunk=8)
            params = model_init(jax.random.PRNGKey(0), cfg, ep_shards=4)
            ocfg = OptConfig(peak_lr=5e-3, warmup_steps=2, total_steps=20)
            opt = init_opt_state(params, ocfg)
            step = jax.jit(functools.partial(train_step, cfg=cfg, opt_cfg=ocfg, loss_chunk=16))
            rng = np.random.default_rng(0)
            for i in range(3):
                t = (rng.integers(0, 32, size=(4, 17)) * 2).astype(np.int32) % 64
                params, opt, m = step(params, opt,
                    {{"tokens": jnp.asarray(t[:, :-1]), "labels": jnp.asarray(t[:, 1:])}})
            CheckpointManager(r"{ckdir}").save(3, {{"params": params, "opt": opt}})
            print("phase1 loss", float(m["loss"]))
        """, n=1)
        # phase 2: restore onto 2x4 mesh with production shardings, keep training
        run_with_devices(f"""
            import jax, jax.numpy as jnp, numpy as np, functools
            from repro.models.transformer import ModelConfig, model_init, ShardCtx
            from repro.optim.adamw import OptConfig, init_opt_state
            from repro.train.steps import train_step
            from repro.checkpoint.manager import CheckpointManager
            from repro.distributed.sharding import param_specs, opt_state_specs, to_named
            mesh = jax.make_mesh((2, 4), ("data", "model"))
            ctx = ShardCtx(mesh=mesh, axes=("data", "model"), ep_axis="model")
            cfg = ModelConfig("e", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                              head_dim=8, d_ff=16, vocab_size=64, pattern=("attn",),
                              ffn_pattern=("moe",), n_experts=4, top_k=2,
                              capacity_factor=8.0, param_dtype=jnp.float32,
                              compute_dtype=jnp.float32, kv_chunk=8)
            params = model_init(jax.random.PRNGKey(0), cfg, ep_shards=4)
            ocfg = OptConfig(peak_lr=5e-3, warmup_steps=2, total_steps=20)
            opt = init_opt_state(params, ocfg)
            pspecs = param_specs(params)
            sh = {{"params": to_named(pspecs, mesh, like=params),
                  "opt": to_named(opt_state_specs(opt, pspecs), mesh, like=opt)}}
            (restored, s) = CheckpointManager(r"{ckdir}").restore(
                {{"params": params, "opt": opt}}, shardings=sh)
            params, opt = restored["params"], restored["opt"]
            step = jax.jit(functools.partial(train_step, cfg=cfg, opt_cfg=ocfg,
                                             ctx=ctx, loss_chunk=16))
            rng = np.random.default_rng(1)
            for i in range(3):
                t = (rng.integers(0, 32, size=(8, 17)) * 2).astype(np.int32) % 64
                params, opt, m = step(params, opt,
                    {{"tokens": jnp.asarray(t[:, :-1]), "labels": jnp.asarray(t[:, 1:])}})
            assert np.isfinite(float(m["loss"]))
            print("phase2 (8-dev) resumed at step", s, "loss", float(m["loss"]))
        """)


def test_cluster_sort_overflow_retry_recovers_losslessly():
    """Model-D regression: a skewed key distribution that overflows the
    slab_geometry capacity must (a) surface the overflow when retries are
    disabled and (b) recover losslessly through the documented
    double-capacity retry — for both cluster_sort and the kv twin."""
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.cluster_sort import cluster_sort, slab_geometry
        from repro.engine import cluster_sort_kv

        mesh = jax.make_mesh((8,), ("x",))
        n, P = 1024, 8
        m = n // P
        rng = np.random.default_rng(0)
        # every key lands in range-bucket 0 of [0, 8000): per-sender counts
        # for that bucket are m, far beyond the provisioned capacity
        x = rng.integers(0, 1000, n).astype(np.int32)
        _, _, cap = slab_geometry("range", m, P, 2.0)
        assert cap < m, (cap, m)  # the skew really does exceed capacity
        kw = dict(mode="range", lo=0, hi=8000)

        try:
            cluster_sort(jnp.asarray(x), mesh, "x", max_retries=0, **kw)
            raise SystemExit("expected capacity-overflow RuntimeError")
        except RuntimeError as e:
            assert "overflow" in str(e)

        # default retries: capacity doubles until cap == m (loss-free bound)
        slab, valid = cluster_sort(jnp.asarray(x), mesh, "x", **kw)
        got = np.asarray(slab)[np.asarray(valid)]
        assert got.shape == (n,), got.shape      # nothing dropped
        assert (got == np.sort(x)).all()         # nothing corrupted

        # the kv twin retries too, carrying its payload losslessly
        v = np.arange(n, dtype=np.int32)
        try:
            cluster_sort_kv(jnp.asarray(x), jnp.asarray(v), mesh, "x",
                            max_retries=0, **kw)
            raise SystemExit("expected kv capacity-overflow RuntimeError")
        except RuntimeError as e:
            assert "overflow" in str(e)
        ref = np.argsort(x, kind="stable")
        sk, sv, valid = cluster_sort_kv(jnp.asarray(x), jnp.asarray(v),
                                        mesh, "x", **kw)
        sk, sv = np.asarray(sk)[np.asarray(valid)], np.asarray(sv)[np.asarray(valid)]
        assert (sk == x[ref]).all() and (sv == ref).all()
        print("overflow retry ok")
    """)
