"""Adversarial skew battery: the sample partition must balance what radix can't.

The PR-8 acceptance contract, pinned as tests:

* On every adversarial distribution (all-equal, Zipfian, one-hot bucket,
  clustered ranges, ±inf / near-inf floats, duplicate-heavy ints) x dtype,
  **sample** mode completes with zero overflow retries and a peak/mean
  bucket ratio <= 1.5 at the default capacity factor — while **radix** mode
  pays at least one capacity-doubling retry on the same data.  Both modes
  stay correct vs ``np.sort`` everywhere.
* The kv paths (``cluster_sort_kv`` / ``argsort``) stay *stable* (match
  ``np.argsort(kind='stable')``) in sample mode.  Stability costs balance on
  tied keys — arrival-order tie ids concentrate each sender's ties — so the
  kv battery asserts correctness, not the zero-retry bound (which belongs
  to the keys-only path, where tie order is unobservable and ids interleave).
* The radix->sample auto-promotion loop works end to end: a persistently
  skewed workload served through ``api.sort`` starts in radix mode, accrues
  strikes, promotes once, runs balanced from then on, and the promotion
  survives a simulated restart through the plan cache.

Multi-device runs execute in a subprocess (forced 8 host devices — the
dry-run isolation rule); one subprocess runs the whole battery and the
parameterized tests assert against its JSON report.  The in-process tests
below cover the promotion policy, the telemetry surface, and the plan-cache
schema without needing devices.
"""
from __future__ import annotations

import json

import numpy as np
import jax.numpy as jnp
import pytest

from conftest import run_with_devices

from repro.engine.adapt import CapacityLearner, LearnedCapacity
from repro.engine.planner import (
    SAMPLE_DEFAULT_FACTOR,
    Planner,
    SortPlan,
    plan_key,
)
from repro.exchange import (
    ExchangeObservation,
    ExchangeTelemetry,
    partition_of,
    splitter_bucket,
    splitters_from_sample,
)

DISTRIBUTIONS = (
    "all_equal",
    "zipf",
    "one_hot",
    "clustered",
    "inf_adjacent",
    "duplicate_heavy",
)
DTYPES = ("int32", "float32")

# the acceptance bound: sample mode's peak bucket load may exceed the mean
# by at most this factor on every adversarial distribution
SAMPLE_RATIO_BOUND = 1.5


# ------------------------------------------------------------------------
# the multi-device battery: one subprocess, JSON report, parameterized asserts
# ------------------------------------------------------------------------
_BATTERY = r"""
import json
import numpy as np
import jax, jax.numpy as jnp
import repro
from repro.core.cluster_sort import cluster_sort
from repro.engine.kv import argsort, cluster_sort_kv

mesh = jax.make_mesh((8,), ("x",))
N = 8192
rng = np.random.default_rng(7)


def make(dist, dtype):
    info = np.iinfo(np.int32)
    if dist == "all_equal":
        k = np.full(N, 7)
    elif dist == "zipf":
        k = np.minimum(rng.zipf(1.5, N), 1 << 30)
    elif dist == "one_hot":  # 95% of keys land in one radix bucket
        k = np.where(rng.random(N) < 0.95, 1000, rng.integers(0, 8000, N))
    elif dist == "clustered":  # tight clusters, big empty gaps between
        k = rng.choice(np.array([0, 3000, 6000]), N) + rng.integers(0, 100, N)
    elif dist == "inf_adjacent":
        if dtype == "float32":  # real infs + near-inf floats + a normal bulk
            bulk = rng.normal(size=N).astype(np.float32)
            k = np.where(rng.random(N) < 0.05, np.float32(np.inf), bulk)
            k = np.where(rng.random(N) < 0.05, np.float32(-np.inf), k)
            k = np.where(rng.random(N) < 0.05, np.float32(3e38), k)
        else:  # int analogue: extremes hugging the dtype endpoints
            k = np.where(rng.random(N) < 0.05, info.max - 3, np.zeros(N))
            k = np.where(rng.random(N) < 0.05, info.min + 3, k)
    elif dist == "duplicate_heavy":
        k = rng.choice(np.array([-3, 0, 7, 7, 42]), N)
    else:
        raise ValueError(dist)
    return k.astype(dtype)


results = []
for dtype in ("int32", "float32"):
    for dist in ("all_equal", "zipf", "one_hot", "clustered", "inf_adjacent",
                 "duplicate_heavy"):
        keys = make(dist, dtype)
        x = jnp.asarray(keys)
        for mode in ("radix", "sample"):
            rows = []
            slab, valid = cluster_sort(
                x, mesh, "x", mode=mode, capacity_factor=2.0,
                telemetry=lambda **kw: rows.append(kw))
            out = np.asarray(slab)[np.asarray(valid)]
            r = rows[-1]
            results.append({
                "kind": "keys", "dist": dist, "dtype": dtype, "mode": mode,
                "correct": bool(np.array_equal(out, np.sort(keys))),
                "retries": int(r["retries"]),
                "ratio": r["peak"] * r["part_buckets"] / r["m"],
                "partition": r["partition"],
            })

# kv stability battery: stable argsort semantics must survive sample mode's
# tie-splitting splitters (correctness contract; balance is keys-only)
for dtype in ("int32", "float32"):
    for dist in ("all_equal", "duplicate_heavy", "zipf"):
        keys = make(dist, dtype)
        expect = np.argsort(keys, kind="stable")
        for mode in ("radix", "sample"):
            idx = argsort(
                jnp.asarray(keys), mesh=mesh, axis="x", mode=mode,
                capacity_factor=2.0, telemetry=lambda **kw: None)
            results.append({
                "kind": "argsort", "dist": dist, "dtype": dtype, "mode": mode,
                "correct": bool(np.array_equal(np.asarray(idx), expect)),
                "retries": -1, "ratio": -1.0, "partition": None,
            })

print("BATTERY=" + json.dumps(results))
"""


@pytest.fixture(scope="module")
def battery():
    out = run_with_devices(_BATTERY, n=8)
    line = next(l for l in out.splitlines() if l.startswith("BATTERY="))
    rows = json.loads(line[len("BATTERY="):])
    return {
        (r["kind"], r["dist"], r["dtype"], r["mode"]): r for r in rows
    }


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("dist", DISTRIBUTIONS)
def test_sample_mode_balances_every_adversarial_distribution(battery, dist, dtype):
    """Sample mode: correct, zero overflow retries, peak/mean <= 1.5 —
    on the exact data where radix mode pays retries."""
    r = battery[("keys", dist, dtype, "sample")]
    assert r["correct"], f"sample mode mis-sorted {dist}/{dtype}"
    assert r["retries"] == 0, f"sample mode overflowed on {dist}/{dtype}: {r}"
    assert r["ratio"] <= SAMPLE_RATIO_BOUND, f"unbalanced on {dist}/{dtype}: {r}"
    assert r["partition"] == "sample"


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("dist", DISTRIBUTIONS)
def test_radix_mode_is_correct_but_retries_under_skew(battery, dist, dtype):
    """Radix mode never corrupts the sort — but every one of these
    distributions overloads a bucket past the default capacity, so each
    costs at least one capacity-doubling retry (the cost promotion exists
    to remove)."""
    r = battery[("keys", dist, dtype, "radix")]
    assert r["correct"], f"radix mode mis-sorted {dist}/{dtype}"
    assert r["retries"] >= 1, f"expected radix overflow on {dist}/{dtype}: {r}"
    assert r["ratio"] > SAMPLE_RATIO_BOUND
    assert r["partition"] == "radix"


@pytest.mark.parametrize("mode", ("radix", "sample"))
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("dist", ("all_equal", "duplicate_heavy", "zipf"))
def test_distributed_argsort_stays_stable(battery, dist, dtype, mode):
    """Tie-heavy distributions through the kv path: both partition modes must
    reproduce np.argsort(kind='stable') exactly — sample mode's composite
    splitters may split a tie run across buckets only in arrival order."""
    r = battery[("argsort", dist, dtype, mode)]
    assert r["correct"], f"{mode}-mode argsort unstable on {dist}/{dtype}"


# ------------------------------------------------------------------------
# end-to-end auto-promotion: radix -> sample through api.sort + plan cache
# ------------------------------------------------------------------------
_PROMOTION = r"""
import json, os
import numpy as np, jax, jax.numpy as jnp
import repro
from repro.engine.planner import Planner, SortPlan, default_planner, plan_key

mesh = jax.make_mesh((8,), ("x",))
pl = default_planner()
assert pl.path, "REPRO_SORT_PLANS must be set for this body"
N = 8192
key = plan_key(N, jnp.int32, mesh)
# the workload starts on a tuned *radix* cluster plan
pl.plans[key] = SortPlan("cluster", mode="radix", capacity_factor=2.0)
pl.save()

rng = np.random.default_rng(1)
x = jnp.asarray(rng.zipf(1.5, N).astype(np.int32))
trace = []
for _ in range(6):
    out = repro.sort(x, mesh=mesh, axis="x")
    assert np.array_equal(
        np.asarray(out[0])[np.asarray(out[1])], np.sort(np.asarray(x)))
    obs = pl.telemetry.last(key)
    part, strikes = pl.promotion_state(key)
    trace.append({
        "partition": obs.partition, "retries": obs.retries,
        "ratio": pl.telemetry.last_ratio(key), "strikes": strikes,
        "promoted": part, "cf": pl.capacity_factor_for(key),
    })

# simulated restart: a fresh planner over the same file must come back
# already promoted and already running sample mode
p2 = Planner(pl.path)
entry = p2.learned[key]
plan2 = p2.plan_for(N, jnp.int32, mesh)
restart = {
    "partition": entry.partition, "strikes": entry.skew_strikes,
    "plan_mode": plan2.partitioner_mode(), "plan_partition": plan2.partition,
}
print("TRACE=" + json.dumps({"trace": trace, "restart": restart}))
"""


def test_auto_promotion_end_to_end(tmp_path):
    import os
    import subprocess
    import sys
    import textwrap

    from conftest import REPO

    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["REPRO_SORT_PLANS"] = str(tmp_path / "plans.json")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_PROMOTION)],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    line = next(l for l in out.stdout.splitlines() if l.startswith("TRACE="))
    doc = json.loads(line[len("TRACE="):])
    trace, restart = doc["trace"], doc["restart"]

    # phase 1: the radix era — skewed, overflowing, accruing strikes
    assert trace[0]["partition"] == "radix"
    assert trace[0]["retries"] >= 1 and trace[0]["ratio"] > 2.0
    assert trace[0]["promoted"] is None
    # promotion latches exactly once the strike threshold is reached
    flip = next(i for i, t in enumerate(trace) if t["promoted"] == "sample")
    assert trace[flip]["strikes"] >= CapacityLearner().promote_after
    # phase 2: the sample era — balanced, zero retries, factor decaying
    post = trace[flip + 1:]
    assert post, "need post-promotion calls in the trace"
    for t in post:
        assert t["partition"] == "sample" and t["retries"] == 0
        assert t["ratio"] <= SAMPLE_RATIO_BOUND
    assert post[-1]["cf"] < trace[flip]["cf"]  # headroom decaying back

    # phase 3: the simulated restart — promotion persisted through the cache
    assert restart["partition"] == "sample"
    assert restart["plan_mode"] == "sample"
    assert restart["plan_partition"] == "sample"

    # and the persisted file itself says v3 with the latch in the entry
    with open(tmp_path / "plans.json") as f:
        saved = json.load(f)
    assert saved["version"] == 3
    (learned_entry,) = [
        v for k, v in saved["learned"].items() if k.startswith("8192|int32|")
    ]
    assert learned_entry["partition"] == "sample"


# ------------------------------------------------------------------------
# in-process: promotion policy, telemetry surface, plan schema (no devices)
# ------------------------------------------------------------------------
def _obs(ratio, *, partition, m=1024, buckets=8, retries=0):
    peak = int(ratio * m / buckets)
    return ExchangeObservation(
        m=m, part_buckets=buckets, capacity=256, peak=peak,
        overflowed=retries > 0, retries=retries, partition=partition,
    )


def test_partition_of_classifies_every_mode():
    assert partition_of("decimal") == "radix"
    assert partition_of("range") == "radix"
    assert partition_of("radix") == "radix"
    assert partition_of("splitters") == "sample"
    assert partition_of("sample") == "sample"
    with pytest.raises(ValueError):
        partition_of("bogus")


def test_promotion_strikes_policy():
    lrn = CapacityLearner()
    # high-ratio radix observations accrue; a calm radix call resets
    s = lrn.promotion_strikes(0, _obs(4.0, partition="radix"))
    s = lrn.promotion_strikes(s, _obs(4.0, partition="radix"))
    assert s == 2 and not lrn.should_promote(s)
    assert lrn.promotion_strikes(s, _obs(1.1, partition="radix")) == 0
    # sample-partition and untagged (MoE) observations pass through unchanged
    assert lrn.promotion_strikes(2, _obs(9.0, partition="sample")) == 2
    assert lrn.promotion_strikes(2, _obs(9.0, partition=None)) == 2
    assert lrn.should_promote(3)


def test_empty_observation_does_not_reset_strikes():
    """Regression: an m=0 observation (idle tick / drained shard) has
    peak_mean_ratio 0.0 by construction, which used to read as "calm" and
    reset the strike counter for a genuinely skewed cell.  The sequence
    [skew, empty, skew, skew] must still promote."""
    lrn = CapacityLearner()
    empty = _obs(0.0, partition="radix", m=0)
    assert empty.m == 0 and empty.peak_mean_ratio() == 0.0
    s = 0
    for o in [_obs(4.0, partition="radix"), empty,
              _obs(4.0, partition="radix"), _obs(4.0, partition="radix")]:
        s = lrn.promotion_strikes(s, o)
    assert s == 3 and lrn.should_promote(s)
    # a genuinely calm radix observation still resets
    assert lrn.promotion_strikes(s, _obs(1.1, partition="radix")) == 0


def test_planner_latches_promotion_and_lowers_the_floor(tmp_path):
    p = Planner(str(tmp_path / "plans.json"))
    key = plan_key(4096, jnp.int32)
    for _ in range(3):
        p.observe_exchange(key, _obs(4.0, partition="radix", retries=1))
    assert p.promotion_state(key) == ("sample", 3)
    # cluster_kwargs with no caller mode injects sample + the lower floor
    kw = p.cluster_kwargs(4096, jnp.int32)
    assert kw["mode"] == "sample"
    # an explicit caller mode is never overridden (no duplicate-kwarg traps)
    assert "mode" not in p.cluster_kwargs(4096, jnp.int32, mode="range")
    # sample-era traffic decays the factor toward the sample floor, and the
    # latch never un-flips
    for _ in range(12):
        p.observe_exchange(
            key, _obs(1.05, partition="sample"), default=SAMPLE_DEFAULT_FACTOR
        )
    assert p.promotion_state(key)[0] == "sample"
    assert p.capacity_factor_for(key, default=SAMPLE_DEFAULT_FACTOR) <= 1.5


def test_plan_for_applies_promotion_to_radix_plans():
    p = Planner()
    key = plan_key(2048, jnp.float32)
    p.plans[key] = SortPlan("cluster", mode="range", capacity_factor=2.0)
    for _ in range(3):
        p.observe_exchange(key, _obs(5.0, partition="radix", retries=1))
    plan = p.plan_for(2048, jnp.float32)
    assert plan.partition == "sample"
    assert plan.partitioner_mode() == "sample"
    assert plan.mode == "range"  # the tuned mode is remembered, not erased
    # a sample-family tuned plan is left alone
    p2 = Planner()
    p2.plans[key] = SortPlan("cluster", mode="splitters")
    for _ in range(3):
        p2.observe_exchange(key, _obs(5.0, partition="radix", retries=1))
    assert p2.plan_for(2048, jnp.float32).partition is None


def test_peak_mean_ratio_surfaces_in_telemetry_and_stats():
    led = ExchangeTelemetry()
    assert led.last_ratio("nope") == 0.0
    led.record("cell", _obs(3.5, partition="radix"))
    assert led.last_ratio("cell") == pytest.approx(3.5, abs=0.01)
    assert _obs(3.5, partition="radix").peak_mean_ratio() == pytest.approx(
        3.5, abs=0.01
    )

    # the ServiceStats surface serve.py --stats prints
    from repro.engine.service import SortService

    p = Planner()
    svc = SortService(planner=p)
    assert svc.stats.peak_mean_ratio == 0.0
    p.observe_exchange("cell", _obs(2.75, partition="radix"))
    p.observe_exchange("cell", _obs(1.5, partition="radix"))
    assert svc.stats.peak_mean_ratio == pytest.approx(2.75, abs=0.01)  # max


def test_sortplan_partition_round_trip_and_v2_load(tmp_path):
    plan = SortPlan("cluster", mode="range", partition="sample")
    assert SortPlan.from_dict(plan.to_dict()) == plan
    assert SortPlan("cluster", mode="decimal").effective_partition() == "radix"
    assert SortPlan("cluster", mode="sample").effective_partition() == "sample"
    assert SortPlan("cluster", mode="splitters").partitioner_mode() == "splitters"
    # a radix override on a sample-family mode runs the radix partitioner
    assert (
        SortPlan("cluster", mode="splitters", partition="radix").partitioner_mode()
        == "radix"
    )

    # graceful v2 load: pre-partition files come back with the new fields at
    # their defaults, and the next save writes schema v3
    path = str(tmp_path / "plans.json")
    v2 = {
        "version": 2,
        "plans": {"1024|int32|local/cpu": {"strategy": "cluster", "mode": "range"}},
        "learned": {
            "1024|int32|local/cpu": {
                "capacity_factor": 3.0, "peak_factor": 2.5, "observations": 4,
            }
        },
    }
    with open(path, "w") as f:
        json.dump(v2, f)
    p = Planner(path)
    assert p.plans["1024|int32|local/cpu"].partition is None
    entry = p.learned["1024|int32|local/cpu"]
    assert entry == LearnedCapacity(3.0, 2.5, 4, partition=None, skew_strikes=0)
    p.save()
    with open(path) as f:
        doc = json.load(f)
    assert doc["version"] == 3
    assert doc["learned"]["1024|int32|local/cpu"]["skew_strikes"] == 0
    # corrupt partition values are rejected, not silently served
    doc["plans"]["1024|int32|local/cpu"]["partition"] = "quantum"
    with open(path, "w") as f:
        json.dump(doc, f)
    with pytest.raises(ValueError):
        Planner().load(path, strict=True)


def test_splitters_from_sample_is_sorted_deduped_deterministic():
    rng = np.random.default_rng(3)
    sample = rng.zipf(1.3, 4096).astype(np.int64)
    a = np.asarray(splitters_from_sample(sample, 16, unique=True))
    b = np.asarray(splitters_from_sample(sample, 16, unique=True))
    assert np.array_equal(a, b)  # deterministic under a fixed sample
    assert np.all(np.diff(a) > 0)  # strictly increasing == sorted + deduped
    assert len(a) <= 15
    # order compatibility: bucket assignment is monotone in the key
    keys = np.sort(rng.zipf(1.3, 512).astype(np.int64))
    buckets = np.asarray(splitter_bucket(jnp.asarray(keys), jnp.asarray(a)))
    assert np.all(np.diff(buckets) >= 0)
