"""Plan-cache concurrency semantics, in-process.

The multihost suite (tests/multihost/) proves the cross-process story with
real ``jax.distributed`` ranks; these tests pin the underlying guarantees
deterministically and cheaply:

* ``Planner.save`` is read-merge-write — two planner instances interleaving
  saves against one file union their plans and merge their learned entries
  (the regression for the old silent last-writer-wins clobber).
* ``plan_key`` / ``parse_plan_key`` round-trip every sort cell, including
  multi-process topology fingerprints (property-based).
* ``LearnedCapacity.merge`` is a semilattice join — commutative,
  associative, idempotent — so any interleaving of rank saves converges.
* The scope policy (``global`` vs ``per_host``) controls key suffixing.
"""
import json
import os
import threading

import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare container: the seeded shim in tests/
    from _hypothesis_shim import given, settings, strategies as st

from repro.engine.adapt import LearnedCapacity
from repro.engine.planner import (
    LEARNED_SCOPES,
    Planner,
    SortPlan,
    parse_plan_key,
    plan_key,
)

settings.register_profile("repro-ci", max_examples=25, deadline=None)
settings.load_profile("repro-ci")


# ------------------------------------------------- interleaved-save union ---
def test_interleaved_planner_saves_union_not_clobber(tmp_path):
    """Two planner instances over one file, neither aware of the other's
    state: after both save, the file carries *everything*."""
    path = str(tmp_path / "plans.json")
    p1, p2 = Planner(path), Planner(path)  # both loaded the (empty) file

    p1.plans["1024|int32|cpu/x=4"] = SortPlan("cluster", capacity_factor=2.5)
    p1.learned["1024|int32|cpu/x=4"] = LearnedCapacity(3.0, 2.6, 5)
    p1.save()

    # p2 still has no idea p1 saved; the old behaviour erased p1's keys here
    p2.plans["4096|float32|cpu/x=8"] = SortPlan("shared")
    p2.learned["moe/E8k2|256|float32|local/cpu"] = LearnedCapacity(4.0, 3.5, 2)
    p2.save()

    fresh = Planner(path)
    assert set(fresh.plans) == {"1024|int32|cpu/x=4", "4096|float32|cpu/x=8"}
    assert set(fresh.learned) == {
        "1024|int32|cpu/x=4",
        "moe/E8k2|256|float32|local/cpu",
    }
    assert fresh.plans["1024|int32|cpu/x=4"].capacity_factor == 2.5
    assert fresh.learned["1024|int32|cpu/x=4"].observations == 5


def test_interleaved_saves_merge_shared_learned_key(tmp_path):
    """Same learned cell in both writers: the more-informed lineage wins the
    factor, peak/observations take the max — in either save order."""
    for flip in (False, True):
        path = str(tmp_path / f"plans-{flip}.json")
        p1, p2 = Planner(path), Planner(path)
        key = "512|int32|cpu/x=2"
        p1.learned[key] = LearnedCapacity(2.0, 2.1, 9)   # more observations
        p2.learned[key] = LearnedCapacity(4.0, 4.2, 3)   # higher factor
        first, second = (p2, p1) if flip else (p1, p2)
        first.save()
        second.save()
        got = Planner(path).learned[key]
        assert got == LearnedCapacity(2.0, 4.2, 9), f"save order flip={flip}"


def test_rotted_file_does_not_block_saving(tmp_path):
    path = str(tmp_path / "plans.json")
    with open(path, "w") as f:
        f.write("{not json")
    p = Planner()
    p.learned["128|int32|local/cpu"] = LearnedCapacity(3.0, 3.0, 1)
    p.save(path)
    assert set(Planner(path).learned) == {"128|int32|local/cpu"}


def test_threaded_saves_keep_every_key(tmp_path):
    """Many threads, each its own Planner instance, hammering one file: the
    flock'd read-merge-write must lose nothing."""
    path = str(tmp_path / "plans.json")
    n_threads, keys_per_thread = 4, 8
    errors = []

    def work(t):
        try:
            p = Planner(path)
            for i in range(keys_per_thread):
                p.learned[f"{2 ** (i + 1)}|int32|cpu/x=2/t{t}"] = LearnedCapacity(
                    2.0 + t, 2.0 + t, 1
                )
                p.save()
        except Exception as e:  # surface thread failures in the test
            errors.append(e)

    threads = [threading.Thread(target=work, args=(t,)) for t in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert not errors
    final = Planner(path)
    assert len(final.learned) == n_threads * keys_per_thread
    with open(path) as f:
        assert json.load(f)["version"] == 3  # file is intact, not torn


# ------------------------------------------------------ key round-tripping ---
_fingerprints = st.sampled_from(
    [
        "local/cpu",
        "local/gpu",
        "cpu/x=2",
        "cpu/x=8",
        "tpu/x=256",
        "gpu/x=4,y=2",
        "local/cpu/procs2x1",
        "cpu/x=4/procs2x2",
        "cpu/x=8/procs4x2",
        "gpu/x=64/procs16x4",
        "tpu/x=256/procs32x8",
    ]
)
_dtypes = st.sampled_from(["int32", "int64", "uint16", "float32", "bfloat16"])


@given(st.integers(1, 1 << 22), _dtypes, _fingerprints)
def test_plan_key_parse_round_trip(n, dtype_name, fp):
    key = plan_key(n, jnp.dtype(dtype_name), fingerprint=fp)
    bucket, parsed_dtype, parsed_fp = parse_plan_key(key)
    assert parsed_dtype == dtype_name
    assert parsed_fp == fp
    assert bucket >= n and bucket < 2 * max(n, 1) + 1  # tight pow2 bucket
    assert bucket & (bucket - 1) == 0
    # rebuilding from the parse lands on the identical key (stable cells)
    assert plan_key(bucket, jnp.dtype(parsed_dtype), fingerprint=parsed_fp) == key


@pytest.mark.parametrize(
    "bad",
    [
        "",
        "4096|int32",
        "4096|int32|cpu/x=2|extra",
        "moe/E8k2|256|float32|local/cpu",  # MoE cells have their own parser
        "notanumber|int32|cpu/x=2",
    ],
)
def test_parse_plan_key_rejects_non_sort_cells(bad):
    with pytest.raises(ValueError):
        parse_plan_key(bad)


# ----------------------------------------------------- merge is a lattice ---
_entries = st.lists(
    st.floats(1.0, 64.0), min_size=3, max_size=3
)  # (cf, peak, raw-obs) triples; obs quantized below


_PARTITION_RANK = {None: 0, "radix": 1, "sample": 2}


def _entry(triple):
    cf, peak, raw = triple
    # partition/strikes/calm/demotions derived from the same floats so the
    # lattice properties get exercised across all partition states and
    # demotion generations without needing richer strategies than the
    # hypothesis shim provides
    parts = (None, "radix", "sample")
    return LearnedCapacity(
        capacity_factor=round(cf, 2),
        peak_factor=round(peak, 2),
        observations=int(raw * 10),
        partition=parts[int(raw * 100) % 3],
        skew_strikes=int(cf * 10) % 7,
        calm_streak=int(peak * 10) % 5,
        demotions=int(peak * 100) % 3,
    )


def _pstate(e):
    """The partition lineage a merge compares: (generation, latch rank)."""
    return (e.demotions, _PARTITION_RANK[e.partition])


@given(_entries, _entries, _entries)
def test_learned_capacity_merge_is_semilattice(a, b, c):
    ea, eb, ec = _entry(a), _entry(b), _entry(c)
    assert ea.merge(ea) == ea                               # idempotent
    assert ea.merge(eb) == eb.merge(ea)                     # commutative
    assert ea.merge(eb).merge(ec) == ea.merge(eb.merge(ec))  # associative
    merged = ea.merge(eb)
    assert merged.peak_factor == max(ea.peak_factor, eb.peak_factor)
    assert merged.observations == max(ea.observations, eb.observations)
    assert merged.capacity_factor in (ea.capacity_factor, eb.capacity_factor)
    # the promotion latch, generation-aware: the newest demotion generation
    # wins, and within it the higher latch — so merge never un-promotes a
    # cell within its generation, and never re-promotes across a demotion
    assert _pstate(merged) == max(_pstate(ea), _pstate(eb))
    if _pstate(ea) == _pstate(eb):
        # same lineage: the counters accumulate (max)
        assert merged.skew_strikes == max(ea.skew_strikes, eb.skew_strikes)
        assert merged.calm_streak == max(ea.calm_streak, eb.calm_streak)
    else:
        # different lineage: the winning entry's counters ride along whole
        win = ea if _pstate(ea) > _pstate(eb) else eb
        assert (merged.skew_strikes, merged.calm_streak) == (
            win.skew_strikes,
            win.calm_streak,
        )


def test_merge_lets_own_decay_win_over_stale_disk_state():
    """The reason merge is lexicographic on (observations, factor): a
    planner's decayed entry must beat its *own* older persisted high-water
    mark, or decay could never reach the disk."""
    stale = LearnedCapacity(5.0, 5.0, 4)      # what this planner saved earlier
    decayed = LearnedCapacity(2.5, 5.0, 9)    # same lineage, more observations
    assert decayed.merge(stale) == decayed
    assert stale.merge(decayed) == decayed


# ------------------------------------------------------------ scope policy ---
def test_scope_policy_controls_key_suffix(monkeypatch):
    key = "4096|int32|cpu/x=2"
    assert Planner().scoped_key(key) == key  # global default
    per_host = Planner(learned_scope="per_host")
    assert per_host.scoped_key(key) == key + "@h0"  # single process: index 0
    monkeypatch.setenv("REPRO_LEARNED_SCOPE", "per_host")
    assert Planner().learned_scope == "per_host"
    with pytest.raises(ValueError):
        Planner(learned_scope="per_rank")
    assert set(LEARNED_SCOPES) == {"global", "per_host"}


def test_per_host_scope_reads_what_it_wrote(tmp_path):
    from repro.exchange import ExchangeObservation

    path = str(tmp_path / "plans.json")
    p = Planner(path, learned_scope="per_host")
    key = plan_key(4096, jnp.int32)
    p.observe_exchange(
        key,
        ExchangeObservation(
            m=128, part_buckets=8, capacity=32, peak=48, overflowed=True, retries=1
        ),
    )
    assert p.capacity_factor_for(key) > 2.0  # read path applies the same scope
    assert set(p.learned) == {key + "@h0"}
    # and the scoped cell still warms on this host
    assert p.warmup_cells() == [(4096, "int32")]
