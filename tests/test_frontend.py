"""Multi-tenant SLO frontend: warmup, EDF scheduling, shed policy, load harness.

Everything timing-sensitive runs on ManualClock — dispatch order, deadline
sheds, latency percentiles, and goodput are deterministic functions of the
seed, which is what the bench's --compare regression gate relies on.
"""
import threading

import numpy as np
import pytest

from repro.engine import (
    ManualClock,
    Planner,
    ShedError,
    SortFrontend,
    SortService,
    Tenant,
    make_trace,
    mesh_fingerprint,
    run_load,
    warmup,
)
from repro.engine.frontend import (
    batch_bucket_ladder,
    linear_service_time,
    payload_for,
    zipf_shares,
)


# ----------------------------------------------------------- trace streams ---
def test_trace_is_byte_for_byte_reproducible():
    kw = dict(duration_s=3.0, rates={"web": 40.0, "batch": 15.0},
              sizes=(64, 128, 256), zipf_a=1.2)
    a = make_trace(seed=42, **kw)
    b = make_trace(seed=42, **kw)
    assert a == b                       # dataclass equality: every field
    assert a != make_trace(seed=43, **kw)
    # payloads too: same (seed, seq) -> identical bytes
    for arr in a[:5]:
        assert payload_for(arr, seed=9).tobytes() == \
            payload_for(arr, seed=9).tobytes()
    assert all(arr.size in (64, 128, 256) for arr in a)
    assert all(0 <= arr.t <= 3.0 for arr in a)
    assert [arr.seq for arr in a] == list(range(len(a)))


def test_trace_tenant_streams_are_independent():
    """Adding a tenant to the mix must not perturb another tenant's stream."""
    solo = make_trace(duration_s=2.0, rates={"a": 20.0}, seed=7)
    mixed = make_trace(duration_s=2.0, rates={"a": 20.0, "b": 80.0}, seed=7)
    a_solo = [(x.t, x.size) for x in solo if x.tenant == "a"]
    a_mixed = [(x.t, x.size) for x in mixed if x.tenant == "a"]
    assert a_solo == a_mixed


def test_zipf_shares_and_size_skew():
    assert zipf_shares(4, 0.0) == (0.25, 0.25, 0.25, 0.25)
    shares = zipf_shares(3, 2.0)
    assert shares[0] > shares[1] > shares[2]
    assert abs(sum(shares) - 1.0) < 1e-12
    with pytest.raises(ValueError):
        zipf_shares(0, 1.0)
    # zipf_a > 0 makes the first (rank-1) size the most common
    tr = make_trace(duration_s=20.0, rates={"t": 50.0}, sizes=(64, 128, 256),
                    zipf_a=2.0, seed=1)
    counts = {s: sum(1 for a in tr if a.size == s) for s in (64, 128, 256)}
    assert counts[64] > counts[128] > counts[256]


def test_trace_rejects_bad_args():
    with pytest.raises(ValueError):
        make_trace(duration_s=0.0, rates={"a": 1.0})
    with pytest.raises(ValueError):
        make_trace(duration_s=1.0, rates={"a": -1.0})


# ------------------------------------------------------------- tenant model ---
def test_tenant_validation():
    with pytest.raises(ValueError):
        Tenant("t", weight=0.0)
    with pytest.raises(ValueError):
        Tenant("t", slo_ms=-5.0)
    with pytest.raises(ValueError):
        SortFrontend(tenants=[Tenant("a"), Tenant("a")])
    with pytest.raises(ValueError):
        SortFrontend(tenants=[])
    fe = SortFrontend(tenants=[Tenant("a")])
    with pytest.raises(KeyError):
        fe.submit("nobody", np.array([1], np.int32))


def test_weighted_backlog_slices():
    fe = SortFrontend(tenants=[Tenant("big", weight=3.0),
                               Tenant("small", weight=1.0),
                               Tenant("pinned", max_backlog=2)],
                      maxsize=40)
    assert fe.tenant_backlog_bound("big") == 24   # ceil(3/5 * 40)
    assert fe.tenant_backlog_bound("small") == 8  # ceil(1/5 * 40)
    assert fe.tenant_backlog_bound("pinned") == 2


# ------------------------------------------------------------ EDF dispatch ---
def test_edf_earlier_deadline_dispatches_first():
    clk = ManualClock()
    fe = SortFrontend(tenants=[Tenant("t")], clock=clk)
    # different sizes -> different signatures -> separate batches, so the
    # pump order exposes the scheduling decision
    relaxed = fe.submit("t", np.arange(256, dtype=np.int32)[::-1],
                        deadline=10.0)
    urgent = fe.submit("t", np.arange(1024, dtype=np.int32)[::-1],
                       deadline=1.0)
    first = fe.pump()
    assert first.bucket == 1024         # urgent (later-submitted) went first
    assert urgent.done() and not relaxed.done()
    fe.poll()
    assert (np.asarray(relaxed.result()) == np.arange(256)).all()


def test_priority_class_beats_deadline():
    clk = ManualClock()
    fe = SortFrontend(tenants=[Tenant("web", priority=0),
                               Tenant("batch", priority=1)], clock=clk)
    # batch has the tighter deadline, but priority classes are strict
    fe.submit("batch", np.arange(256, dtype=np.int32), deadline=0.5)
    fe.submit("web", np.arange(1024, dtype=np.int32), deadline=100.0)
    assert fe.pump().bucket == 1024
    fe.poll()


def test_compatible_requests_coalesce_across_tenants():
    clk = ManualClock()
    fe = SortFrontend(tenants=[Tenant("web", priority=0),
                               Tenant("batch", priority=1)],
                      max_batch=8, clock=clk)
    t1 = fe.submit("batch", np.array([5, 3, 4], np.int32))
    t2 = fe.submit("web", np.array([2, 9, 1], np.int32))
    info = fe.pump()                    # one batch, both tenants ride along
    assert info.n_requests == 2 and set(info.tenants) == {"web", "batch"}
    assert [int(v) for v in t1.result()] == [3, 4, 5]
    assert [int(v) for v in t2.result()] == [1, 2, 9]
    assert fe.stats.tenant_served == {"web": 1, "batch": 1}


# -------------------------------------------------------------- load shed ---
def test_shed_at_global_and_tenant_bounds():
    clk = ManualClock()
    fe = SortFrontend(tenants=[Tenant("a", weight=1.0),
                               Tenant("b", weight=1.0)],
                      maxsize=4, clock=clk)
    assert fe.tenant_backlog_bound("a") == 2
    req = np.array([1], np.int32)
    fe.submit("a", req), fe.submit("a", req)
    with pytest.raises(ShedError) as ei:
        fe.submit("a", req)             # a's weighted slice (2) is full
    assert ei.value.reason == "tenant_backlog" and ei.value.tenant == "a"
    fe.submit("b", req), fe.submit("b", req)
    with pytest.raises(ShedError) as ei:
        fe.submit("b", req)             # whole backlog (4) is full
    assert ei.value.reason == "global_backlog"
    # attribution: the right tenant, the right reason, the shared ledger
    assert fe.stats.shed == {"a": {"tenant_backlog": 1},
                             "b": {"global_backlog": 1}}
    assert fe.stats.shed_total() == 2 == fe.stats.rejected
    assert fe.stats.shed_total("a") == 1
    fe.poll()


def test_expired_requests_shed_at_dispatch_with_reason():
    clk = ManualClock()
    fe = SortFrontend(tenants=[Tenant("t", slo_ms=50.0)], clock=clk)
    late = fe.submit("t", np.array([3, 1], np.int32))   # deadline = 0.05
    clk.advance(0.2)
    fresh = fe.submit("t", np.array([2, 4], np.int32))
    fe.poll()
    with pytest.raises(ShedError) as ei:
        late.result()
    assert ei.value.reason == "deadline"
    assert late.latency_s == pytest.approx(0.2)
    assert not late.slo_met
    assert [int(v) for v in fresh.result()] == [2, 4]
    assert fe.stats.shed == {"t": {"deadline": 1}}


def test_shed_expired_false_serves_late_and_counts_the_miss():
    clk = ManualClock()
    fe = SortFrontend(tenants=[Tenant("t", slo_ms=50.0)],
                      shed_expired=False, clock=clk)
    late = fe.submit("t", np.array([3, 1], np.int32))
    clk.advance(0.2)
    fe.poll()
    assert [int(v) for v in late.result()] == [1, 3]    # answered anyway
    assert not late.slo_met                             # ...but missed SLO
    assert fe.stats.shed_total() == 0


# ------------------------------------------------------------- AOT warmup ---
def test_batch_bucket_ladder():
    assert batch_bucket_ladder(1) == (1,)
    assert batch_bucket_ladder(8) == (1, 2, 4, 8)
    assert batch_bucket_ladder(5) == (1, 2, 4, 8)


def test_warm_cell_idempotent():
    svc = SortService()
    assert svc.warm_cell("sort", 1024, "int32") is True    # fresh compile
    assert svc.warm_cell("sort", 1024, "int32") is False   # already warm
    assert svc.stats.compiles == 1 and svc.stats.cache_hits == 1


def test_planner_warmup_cells_skips_moe_and_foreign_mesh():
    from repro.engine import SortPlan
    p = Planner()
    fp = mesh_fingerprint(None)
    p.plans[f"1024|int32|{fp}"] = SortPlan("shared")
    p.plans[f"moe/E8k2|256|float32|{fp}"] = SortPlan("shared")
    p.plans["4096|int32|mesh[x=4]"] = SortPlan("cluster")
    cells = p.warmup_cells()
    assert cells == [(1024, "int32")]   # moe + foreign-mesh keys skipped


def test_warmup_then_zero_lowerings_on_warmed_traffic():
    """Acceptance: after warmup(plan_table), serving any warmed cell performs
    zero fresh compiles — jax's own lowering counter, not just ours."""
    from jax._src import test_util as jtu

    from repro.engine import SortPlan
    planner = Planner()
    planner.plans[f"512|int32|{mesh_fingerprint(None)}"] = SortPlan("shared")
    svc = SortService(planner=planner)
    fe = SortFrontend(svc, tenants=[Tenant("t")], max_batch=4)
    report = fe.warmup(plan_table=planner, cells=[(1000, "int32")],
                       kinds=("sort", "argsort"))
    # (512 + 1024 buckets) x (sort, argsort) x bb ladder (1, 2, 4)
    assert report.compiled == 12 and report.cached == 0
    assert fe.warmup(plan_table=planner, cells=[(1000, "int32")],
                     kinds=("sort", "argsort")).compiled == 0

    rng = np.random.default_rng(0)
    with jtu.count_jit_and_pmap_lowerings() as count:
        tickets = [
            fe.submit("t", rng.integers(0, 1000, n).astype(np.int32),
                      kind=kind)
            for kind in ("sort", "argsort") for n in (400, 500, 900)
        ]
        fe.poll()
    assert count[0] == 0, "warmed cells must never re-trace"
    for t in tickets[:3]:
        assert np.asarray(t.result()).min() >= 0
    srt = np.asarray(tickets[0].result())
    assert (srt[:-1] <= srt[1:]).all()


# ----------------------------------------------------- overload simulation ---
def _overload_run():
    clk = ManualClock()
    fe = SortFrontend(
        SortService(),
        tenants=[Tenant("web", weight=2.0, priority=0, slo_ms=40.0),
                 Tenant("batch", weight=1.0, priority=1, slo_ms=200.0)],
        max_batch=4, maxsize=32, clock=clk,
    )
    tr = make_trace(duration_s=1.0, rates={"web": 700.0, "batch": 500.0},
                    sizes=(64, 128), seed=5)
    rep = run_load(fe, tr, clock=clk,
                   service_time=linear_service_time(base_ms=5.0,
                                                    us_per_key=0.02))
    return fe, rep


def test_overload_simulation_is_deterministic():
    fe1, rep1 = _overload_run()
    fe2, rep2 = _overload_run()
    assert rep1.derived() == rep2.derived()
    assert rep1.derived("web") == rep2.derived("web")
    assert len(rep1.tickets) == len(rep2.tickets)
    assert rep1.sheds == rep2.sheds
    assert fe1.stats.shed == fe2.stats.shed


def test_overload_priority_protects_the_interactive_tenant():
    fe, rep = _overload_run()
    # offered 1200/s vs ~800/s capacity: somebody lost — and the scheduler
    # must have made it the low-priority tenant, not the interactive one
    assert rep.offered == len(rep.tickets) + sum(
        1 for _ in rep.sheds) - sum(
        1 for t in rep.tickets
        if t.done() and isinstance(t.future.exception(), ShedError))
    assert 0.0 < rep.goodput() < 1.0
    assert rep.goodput("web") > rep.goodput("batch")
    assert rep.latency_percentiles(tenant="web")[95] <= 0.040 + 1e-9
    # every shed is attributed: report ledger totals == stats ledger totals
    assert len(rep.sheds) == fe.stats.shed_total()


# ------------------------------------------------------------- thread mode ---
def test_thread_mode_smoke():
    fe = SortFrontend(tenants=[Tenant("a"), Tenant("b")],
                      max_batch=8, start=True)
    results = {}

    def client(name, n_reqs):
        rng = np.random.default_rng(ord(name))
        got = []
        for _ in range(n_reqs):
            arr = rng.integers(0, 10_000, 200).astype(np.int32)
            got.append((arr, fe.submit(name, arr)))
        results[name] = got

    threads = [threading.Thread(target=client, args=(n, 8)) for n in "ab"]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    with fe:                            # context manager drains + closes
        pass
    for name, got in results.items():
        for arr, ticket in got:
            assert (np.asarray(ticket.result()) == np.sort(arr)).all()
    assert fe.stats.tenant_served == {"a": 8, "b": 8}
    with pytest.raises(RuntimeError):
        fe.submit("a", np.array([1], np.int32))


def test_engine_level_warmup_entry_point():
    svc = SortService(planner=Planner())    # hermetic plan table
    rep = warmup(svc, cells=[(256, "int32")], kinds=("sort",), max_batch=2)
    assert rep.compiled == 2 and "warmup:" in rep.summary()
    assert rep.cells == [("sort", 256, "int32", bb, True) for bb in (1, 2)]


def test_replay_wallclock_smoke():
    """Real-time replay: same report type as the simulation, real clock."""
    from repro.engine.frontend import replay_wallclock

    fe = SortFrontend(SortService(), tenants=[Tenant("t", slo_ms=60_000.0)],
                      max_batch=4, start=True)
    fe.warmup(cells=[(128, "int32")], kinds=("sort",))
    tr = make_trace(duration_s=0.2, rates={"t": 40.0}, sizes=(64, 128),
                    seed=3)
    rep = replay_wallclock(fe, tr, seed=3)
    fe.close()
    assert rep.offered == len(tr) and len(rep.tickets) == len(tr)
    assert rep.goodput() == 1.0 and rep.shed_counts() == {}
    assert rep.elapsed_s >= 0.2
    pct = rep.latency_percentiles((50, 99))
    assert 0.0 <= pct[50] <= pct[99]


def test_pump_execution_failure_resolves_tickets_exceptionally():
    clk = ManualClock()
    svc = SortService()
    fe = SortFrontend(svc, tenants=[Tenant("t")], clock=clk)
    t1 = fe.submit("t", np.array([2, 1], np.int32))
    t2 = fe.submit("t", np.array([4, 3], np.int32))

    def boom(*a, **k):
        raise RuntimeError("executor died")

    svc._run_group = boom
    info = fe.pump()
    assert info.n_requests == 2
    for t in (t1, t2):
        with pytest.raises(RuntimeError, match="executor died"):
            t.result()
        assert t.latency_s is not None          # failure still stamps t_done


def test_warmup_sort_kv_cells_via_values_spec():
    svc = SortService(planner=Planner())
    rep = warmup(svc, cells=[(64, "int32")], kinds=("sort_kv",),
                 max_batch=1, values_spec=((), "float32"))
    assert rep.compiled == 1
    fe = SortFrontend(svc, tenants=[Tenant("t")], max_batch=1)
    keys = np.arange(40, 0, -1).astype(np.int32)        # len 40 -> 64 bucket
    t = fe.submit("t", keys, kind="sort_kv",
                  values=keys.astype(np.float32) / 10.0)
    compiles_before = svc.cache.misses
    fe.poll()
    sk, sv = t.result()
    assert [int(v) for v in sk[:3]] == [1, 2, 3]
    assert np.allclose(np.asarray(sv), np.asarray(sk) / 10.0)
    # warmed via values_spec: the serving submit was a pure cache hit
    assert svc.cache.misses == compiles_before


def test_backlog_views_and_double_close():
    clk = ManualClock()
    fe = SortFrontend(tenants=[Tenant("a"), Tenant("b")], clock=clk)
    fe.submit("a", np.array([1], np.int32))
    assert fe.backlog() == 1 and fe.backlog("a") == 1 and fe.backlog("b") == 0
    fe.close()
    fe.close()                                  # idempotent
    assert fe.backlog() == 0
