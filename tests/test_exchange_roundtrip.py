"""partition_exchange -> combine_exchange round-trip contract (multi-device):
values pytrees come back in original order, dropped overflow elements get
``fill``, and the compressed wire mode has a usable straight-through VJP."""
from conftest import run_with_devices


def test_roundtrip_restores_order_and_fills_drops():
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.core import partition_exchange, combine_exchange
        mesh = jax.make_mesh((8,), ("x",))
        rng = np.random.default_rng(0)
        m, P_ = 100, 8
        k = rng.integers(0, 1000, size=(m * P_,)).astype(np.int32)
        v = {"a": rng.standard_normal((m * P_, 4)).astype(np.float32),
             "b": np.arange(m * P_, dtype=np.int32)}

        def body(k, v, cap):
            dest = (k % P_).astype(jnp.int32)
            ex = partition_exchange(k, v, dest, "x", capacity=cap)
            back = combine_exchange(ex.recv_values, ex, "x", fill=-7)
            kept = ex.send_slot >= 0
            return back, kept, ex.overflow

        def run(cap):
            return jax.jit(jax.shard_map(
                lambda kk, vv: body(kk, vv, cap), mesh=mesh,
                in_specs=(P("x"), P("x")),
                out_specs=({"a": P("x"), "b": P("x")}, P("x"), P()),
            ))(jnp.asarray(k), jax.tree.map(jnp.asarray, v))

        # loss-free capacity: exact round trip, no overflow
        back, kept, ovf = run(m)
        assert not bool(ovf)
        assert bool(kept.all())
        assert (np.asarray(back["a"]) == v["a"]).all()
        assert (np.asarray(back["b"]) == v["b"]).all()

        # tight capacity: overflow flagged, survivors exact, drops filled
        back, kept, ovf = run(4)
        kept = np.asarray(kept)
        assert bool(ovf) and not kept.all()
        assert (np.asarray(back["a"])[kept] == v["a"][kept]).all()
        assert (np.asarray(back["a"])[~kept] == -7).all()
        assert (np.asarray(back["b"])[~kept] == -7).all()
        print("roundtrip contract ok")
    """)


def test_compressed_exchange_straight_through_gradients():
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.core import partition_exchange, combine_exchange
        mesh = jax.make_mesh((8,), ("x",))
        rng = np.random.default_rng(1)
        m, P_ = 64, 8
        k = jnp.asarray(rng.integers(0, 1000, size=(m * P_,)), jnp.int32)
        v = jnp.asarray(rng.standard_normal((m * P_, 8)), jnp.float32)

        def body(k, v):
            dest = (k % P_).astype(jnp.int32)
            ex = partition_exchange(k, v, dest, "x", capacity=m, compress=True)
            y = combine_exchange(ex.recv_values, ex, "x")
            return jnp.sum(y * y)[None]

        def loss(v):
            parts = jax.shard_map(body, mesh=mesh,
                in_specs=(P("x"), P("x")), out_specs=P("x"))(k, v)
            return jnp.sum(parts)

        val, g = jax.jit(jax.value_and_grad(loss))(v)
        g = np.asarray(g)
        assert np.isfinite(float(val))
        assert np.isfinite(g).all(), "straight-through VJP must be finite"
        assert np.abs(g).max() > 0, "gradients must flow through the wire"
        # straight-through ~= d/dv sum(v^2) = 2v (up to int8 quantization)
        rel = np.abs(g - 2 * np.asarray(v)).max() / np.abs(2 * np.asarray(v)).max()
        assert rel < 0.05, rel
        print("compressed vjp ok")
    """)
