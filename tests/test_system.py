"""End-to-end system tests: the training driver and serving driver run,
converge, checkpoint-restart works, and the dry-run machinery's loop-aware
collective accounting parses real HLO."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.serve import main as serve_main
from repro.launch.train import main as train_main


def test_train_driver_end_to_end(tmp_path):
    losses = train_main([
        "--arch", "qwen3-0.6b", "--reduced", "--steps", "12", "--batch", "4",
        "--seq", "32", "--lr", "5e-3", "--ckpt-dir", str(tmp_path),
        "--ckpt-every", "6", "--log-every", "100",
    ])
    assert losses[-1] < losses[0]
    from repro.checkpoint.manager import CheckpointManager

    assert CheckpointManager(str(tmp_path)).latest_step() == 12


def test_serve_driver_end_to_end():
    gen = serve_main([
        "--arch", "qwen3-0.6b", "--reduced", "--batch", "2",
        "--prompt-len", "12", "--gen", "4",
    ])
    assert gen.shape == (2, 4)


def test_serve_driver_topk_queue_matches_direct_path():
    """--topk-queue (per-row argsort through AsyncSortService) samples the
    same tokens as the direct engine.topk path — same seed, same model."""
    args = ["--arch", "qwen3-0.6b", "--reduced", "--batch", "2",
            "--prompt-len", "12", "--gen", "4"]
    direct = serve_main(args)
    queued = serve_main(args + ["--topk-queue"])
    assert queued.shape == (2, 4)
    assert (queued == direct).all()


def test_serve_driver_multi_tenant_frontend_matches_direct_path(capsys):
    """--tenants + --warmup (rows through the SLO SortFrontend) samples the
    same tokens as the direct path, serves every row (shed_expired=False on
    the decode path), and pays zero compiles once traffic starts."""
    args = ["--arch", "qwen3-0.6b", "--reduced", "--batch", "2",
            "--prompt-len", "12", "--gen", "4"]
    direct = serve_main(args)
    capsys.readouterr()
    fronted = serve_main(args + ["--tenants", "web:3:0,batch:1:1",
                                 "--warmup", "--slo-ms", "60000", "--stats"])
    out = capsys.readouterr().out
    assert (fronted == direct).all()
    assert "compiled" in out                       # warmup report printed
    assert "slo_misses=0/8" in out                 # 2 rows x 4 steps, all met
    assert "web=4" in out and "batch=4" in out     # round-robin row split
    assert "shed=0" in out


def test_collective_parser_on_real_hlo():
    """Loop-aware accounting: a psum inside a scan counts trip_count times."""
    from jax.sharding import PartitionSpec as P

    from repro.launch.dryrun import collective_bytes

    mesh = jax.make_mesh((1,), ("x",))

    def body(x):
        def inner(c, i):
            return c + (jax.lax.psum(x * i, "x")).sum(), None

        out, _ = jax.lax.scan(inner, 0.0, jnp.arange(5.0))
        return out[None]

    f = jax.jit(jax.shard_map(body, mesh=mesh, in_specs=P("x"), out_specs=P("x")))
    txt = f.lower(jnp.ones((8, 4), jnp.float32)).compile().as_text()
    res = collective_bytes(txt)
    # x*i is loop-varying so the psum must stay inside the while: 5 x 128 bytes
    # (or the compiler removed the trivial 1-device collective entirely — then
    # both counts are zero and the parser must agree)
    assert res["total_bytes"] in (640, 0), res
