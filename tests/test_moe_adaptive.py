"""MoE capacity learning through the unified exchange layer.

The acceptance regression: a skewed router pays its overflow/drop retry
exactly once per process and zero after a simulated restart (asserted with
jax's lowering counters, mirroring tests/test_adapt.py), plus property
tests that learned expert capacity factors stay within learner bounds and
that the hoisted capacity formula drives both MoE forwards.
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare container — requirements-dev.txt installs the real one
    from _hypothesis_shim import given, settings, strategies as st

from repro.engine import CapacityLearner, ExchangeObservation, Planner
from repro.exchange import expert_capacity
from repro.models.moe import (
    MoEConfig,
    collapse_router,
    moe_apply_adaptive,
    moe_apply_ep_replicated,
    moe_init,
    moe_plan_key,
)

settings.register_profile("repro-ci", max_examples=10, deadline=None,
                          derandomize=True)
settings.load_profile("repro-ci")

DEFAULT_CF = 2.0


def _collapsed_moe(key, *, n_experts=8, top_k=1, capacity_factor=DEFAULT_CF):
    """An MoE layer with worst-case routing skew (collapse_router) — what
    the capacity loop exists for."""
    cfg = MoEConfig(d_model=16, d_ff=8, n_experts=n_experts, top_k=top_k,
                    capacity_factor=capacity_factor)
    return cfg, collapse_router(moe_init(key, cfg, jnp.float32, ep_shards=1))


# ----------------------------------------------- acceptance regression ------
def test_skewed_router_pays_retry_once_and_zero_after_restart(key):
    """ISSUE acceptance: first adaptive call overflows, retries to the
    loss-free bound, and teaches the planner; the same cell then serves with
    zero retries and — via jax's lowering counters — zero fresh traces; a
    fresh planner over the same JSON (simulated restart) starts at the
    learned factor so its first call pays nothing either."""
    from jax._src import test_util as jtu

    cfg, p = _collapsed_moe(key)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    path = os.path.join(tempfile.mkdtemp(), "plans.json")
    planner = Planner(path)
    cell = moe_plan_key(64, cfg, x.dtype)

    # call 1: the default factor under-provisions the hot expert -> retries
    y1, aux1, counts = moe_apply_adaptive(p, cfg, x, planner=planner)
    obs1 = planner.telemetry.last(cell)
    assert obs1 is not None and obs1.overflowed and obs1.retries >= 1
    # the retry recomputed the overflowed attempts: nothing reached the
    # served output, everything shows up as averted
    assert obs1.recompiles >= 1
    assert obs1.dropped == 0 and obs1.dropped_averted > 0
    cf = planner.capacity_factor_for(cell, default=cfg.capacity_factor)
    assert cf > cfg.capacity_factor
    assert cf >= obs1.required_factor()
    # the hot expert really did absorb the skew
    assert int(np.asarray(counts).max()) == obs1.peak

    # the final attempt ran loss-free: output == an over-provisioned forward
    y_ref, _, ovf = moe_apply_ep_replicated(
        p, cfg._replace(capacity_factor=float(cfg.n_experts * cfg.top_k)), x)
    assert not bool(ovf)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y_ref), atol=1e-5)

    # call 2: learned factor -> zero retries, zero drops
    y2, _, _ = moe_apply_adaptive(p, cfg, x, planner=planner)
    obs2 = planner.telemetry.last(cell)
    assert not obs2.overflowed and obs2.retries == 0 and obs2.dropped == 0
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_ref), atol=1e-5)

    # steady state: same cell, zero retries AND zero fresh lowerings
    with jtu.count_jit_and_pmap_lowerings() as count:
        moe_apply_adaptive(p, cfg, x, planner=planner)
    assert count[0] == 0, "steady-state MoE dispatch must not re-trace"
    assert planner.telemetry.last(cell).retries == 0

    # restart: a fresh planner over the same JSON starts provisioned
    restarted = Planner(path)
    assert restarted.capacity_factor_for(cell, default=cfg.capacity_factor) == cf
    with jtu.count_jit_and_pmap_lowerings() as count:
        y3, _, _ = moe_apply_adaptive(p, cfg, x, planner=restarted)
    assert count[0] == 0, "post-restart first call must reuse the executable"
    assert restarted.telemetry.last(cell).retries == 0
    np.testing.assert_allclose(np.asarray(y3), np.asarray(y_ref), atol=1e-5)


def test_fixed_capacity_path_reports_real_drops(key):
    """max_retries=0 is the GShard fixed path: overflow drops tokens instead
    of raising (strict=False in the shared driver), and the drop count lands
    in the telemetry ledger — the previously-silent signal serve.py --stats
    now prints."""
    cfg, p = _collapsed_moe(key)
    x = jax.random.normal(jax.random.PRNGKey(2), (64, 16))
    planner = Planner()
    cell = moe_plan_key(64, cfg, x.dtype)

    y_drop, _, _ = moe_apply_adaptive(p, cfg, x, planner=planner, max_retries=0)
    obs = planner.telemetry.last(cell)
    assert obs.overflowed and obs.retries == 0 and obs.dropped > 0
    assert obs.dropped_averted == 0, "no retry ran, so nothing was averted"
    assert planner.telemetry.total_dropped == obs.dropped

    y_ref, _, _ = moe_apply_ep_replicated(
        p, cfg._replace(capacity_factor=float(cfg.n_experts * cfg.top_k)), x)
    assert not np.allclose(np.asarray(y_drop), np.asarray(y_ref)), \
        "dropped tokens must actually be missing from the output"


def test_explicit_sort_plan_pin_opts_out_of_the_loop(debug_mesh):
    """api.sort with an explicit plan= pins the whole recipe: it must not
    read a learned factor over the pin, nor inflate the shared learned
    table with the pin as the learner floor."""
    import jax

    from repro.core import sort
    from repro.engine import SortPlan
    from repro.engine.planner import default_planner, plan_key

    planner = default_planner()
    n = 64
    x = jax.random.randint(jax.random.PRNGKey(8), (n,), 0, 1000, jnp.int32)
    cell = plan_key(n, jnp.int32, debug_mesh)
    calls_before = planner.telemetry.calls
    learned_before = dict(planner.learned)
    slab, valid = sort(x, mesh=debug_mesh, axis="x",
                       plan=SortPlan("cluster", capacity_factor=8.0))
    assert (np.asarray(slab)[np.asarray(valid)] == np.sort(np.asarray(x))).all()
    assert planner.telemetry.calls == calls_before, "pinned call reported"
    assert planner.learned.get(cell) == learned_before.get(cell), \
        "pinned call mutated the shared learned table"


def test_explicit_capacity_factor_opts_out_of_the_loop(key):
    """Like the sort paths: an explicit capacity_factor= neither reads nor
    writes the planner's learned table."""
    cfg, p = _collapsed_moe(key)
    x = jax.random.normal(jax.random.PRNGKey(3), (64, 16))
    planner = Planner()
    y, _, _ = moe_apply_adaptive(
        p, cfg, x, planner=planner, capacity_factor=float(cfg.n_experts))
    assert planner.telemetry.calls == 0
    assert planner.learned == {}


# ------------------------------------------------------ shared capacity -----
def test_moe_forwards_use_the_hoisted_capacity_formula(key):
    """capacity= overrides must reproduce the cfg-derived default exactly —
    i.e. both forwards consume expert_capacity, not a re-derived copy."""
    cfg = MoEConfig(d_model=16, d_ff=8, n_experts=4, top_k=2,
                    capacity_factor=1.3)
    p = moe_init(key, cfg, jnp.float32, ep_shards=1)
    x = jax.random.normal(jax.random.PRNGKey(4), (24, 16))
    cap = expert_capacity(24, cfg.top_k, cfg.n_experts, cfg.capacity_factor)
    y_default, _, ovf_d = moe_apply_ep_replicated(p, cfg, x)
    y_explicit, _, ovf_e = moe_apply_ep_replicated(p, cfg, x, capacity=cap)
    np.testing.assert_array_equal(np.asarray(y_default), np.asarray(y_explicit))
    assert bool(ovf_d) == bool(ovf_e)


def test_with_stats_is_consistent_with_plain_forward(key):
    """with_stats=True must not perturb the computation, and its counts/peak
    must describe the routing exactly."""
    cfg = MoEConfig(d_model=16, d_ff=8, n_experts=4, top_k=2,
                    capacity_factor=8.0)
    p = moe_init(key, cfg, jnp.float32, ep_shards=1)
    x = jax.random.normal(jax.random.PRNGKey(5), (32, 16))
    y, aux, ovf = moe_apply_ep_replicated(p, cfg, x)
    ys, auxs, dropped, counts, peak, ovfs = moe_apply_ep_replicated(
        p, cfg, x, with_stats=True)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(ys))
    assert float(aux) == float(auxs)
    assert int(dropped) == 0 and not bool(ovfs)
    assert int(np.asarray(counts).sum()) == 32 * cfg.top_k
    assert int(peak) == int(np.asarray(counts).max())


# -------------------------------------------- local (all_to_all) dispatch ---
def test_moe_apply_local_matches_replicated_on_one_shard(key):
    """moe_apply_local (the all_to_all dispatch) on a 1-device EP mesh must
    equal the replicated fallback exactly — the two forwards are the same
    exchange consumed two ways, and this runs the wire path in-process."""
    from jax.sharding import PartitionSpec as PS

    from repro.models.moe import moe_apply_local, moe_shard_specs

    cfg = MoEConfig(d_model=16, d_ff=8, n_experts=4, top_k=2,
                    capacity_factor=8.0)
    p = moe_init(key, cfg, jnp.float32, ep_shards=1)
    x = jax.random.normal(jax.random.PRNGKey(6), (32, 16))
    mesh = jax.make_mesh((1,), ("model",))
    (p_spec, x_spec), out_specs = moe_shard_specs(p, mesh_axes=("model",))

    y_local, aux_l, ovf_l = jax.shard_map(
        lambda mp, xt: moe_apply_local(mp, cfg, xt, "model", ("model",)),
        mesh=mesh, in_specs=(p_spec, x_spec), out_specs=out_specs,
        check_vma=False)(p, x)
    y_rep, aux_r, ovf_r = moe_apply_ep_replicated(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y_local), np.asarray(y_rep),
                               atol=1e-5)
    assert float(aux_l) == pytest.approx(float(aux_r))
    assert not bool(ovf_l) and not bool(ovf_r)

    # with_stats twin: same output, counts describe the routing exactly
    stats_specs = (out_specs[0], PS(), PS(), PS(), PS(), PS())
    ys, _, dropped, counts, peak, _ = jax.shard_map(
        lambda mp, xt: moe_apply_local(mp, cfg, xt, "model", ("model",),
                                       with_stats=True),
        mesh=mesh, in_specs=(p_spec, x_spec), out_specs=stats_specs,
        check_vma=False)(p, x)
    np.testing.assert_allclose(np.asarray(ys), np.asarray(y_rep), atol=1e-5)
    assert int(dropped) == 0
    assert int(np.asarray(counts).sum()) == 32 * cfg.top_k
    assert int(peak) == int(np.asarray(counts).max())

    # the replicated forward's EP-axis branch (decode path) agrees too
    y_ep, _, ovf_ep = jax.shard_map(
        lambda mp, xt: moe_apply_ep_replicated(mp, cfg, xt, "model",
                                               ("model",)),
        mesh=mesh, in_specs=(p_spec, PS()), out_specs=(PS(), PS(), PS()),
        check_vma=False)(p, x)
    np.testing.assert_allclose(np.asarray(y_ep), np.asarray(y_rep), atol=1e-5)
    assert not bool(ovf_ep)


def test_moe_apply_local_compressed_dispatch_close_to_exact(key):
    """compress_dispatch=True rides the exchange layer's int8 wire; outputs
    stay within quantization tolerance of the exact forward."""
    from repro.models.moe import moe_apply_local, moe_shard_specs

    cfg = MoEConfig(d_model=16, d_ff=8, n_experts=4, top_k=2,
                    capacity_factor=8.0, compress_dispatch=True)
    p = moe_init(key, cfg, jnp.float32, ep_shards=1)
    x = jax.random.normal(jax.random.PRNGKey(7), (32, 16))
    mesh = jax.make_mesh((1,), ("model",))
    (p_spec, x_spec), out_specs = moe_shard_specs(p, mesh_axes=("model",))
    y_c, _, _ = jax.shard_map(
        lambda mp, xt: moe_apply_local(mp, cfg, xt, "model", ("model",)),
        mesh=mesh, in_specs=(p_spec, x_spec), out_specs=out_specs,
        check_vma=False)(p, x)
    y_exact, _, _ = moe_apply_ep_replicated(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_exact),
                               atol=0.15)


# ------------------------------------------------------- learner bounds -----
cfs = st.floats(0.05, 8.0)
Ts = st.sampled_from((16, 64, 256))
Es = st.sampled_from((2, 4, 8, 16))
ks = st.integers(1, 4)
seeds = st.integers(0, 2**20)


def _moe_observation(rng, T, E, k):
    m = T * k
    peak = int(rng.integers(0, m + 1))
    cap = expert_capacity(T, k, E, DEFAULT_CF)
    overflowed = peak > cap
    return ExchangeObservation(
        m=m, part_buckets=E, capacity=max(cap, peak if overflowed else cap),
        peak=peak, overflowed=overflowed, retries=int(overflowed),
        dropped=max(0, peak - cap) if overflowed else 0)


@given(st.integers(1, 40), Ts, Es, ks, seeds)
def test_learned_expert_factors_stay_within_learner_bounds(n_obs, T, E, k, seed):
    """For ANY sequence of MoE-shaped observations the planner's learned
    expert capacity factor stays within [default, max_factor] — routing
    chaos cannot run capacity (or expert-buffer memory) away."""
    rng = np.random.default_rng(seed)
    planner = Planner()
    learner = CapacityLearner()
    cell = f"moe/E{E}k{k}|{T}|float32|local/cpu"
    for _ in range(n_obs):
        planner.observe_exchange(
            cell, _moe_observation(rng, T, E, k), default=DEFAULT_CF)
        cf = planner.capacity_factor_for(cell, default=DEFAULT_CF)
        assert DEFAULT_CF <= cf <= learner.max_factor
        # the factor is always realizable as a concrete expert capacity
        assert 1 <= expert_capacity(T, k, E, cf) <= T * k


@given(Ts, Es, ks, cfs)
def test_learned_factor_roundtrips_to_a_fitting_capacity(T, E, k, cf):
    """required_factor -> expert_capacity closes: learning from a peak and
    re-deriving the capacity always fits that peak (margin >= 1)."""
    rng = np.random.default_rng(0)
    peak = int(rng.integers(1, T * k + 1))
    obs = ExchangeObservation(m=T * k, part_buckets=E, capacity=peak,
                              peak=peak, overflowed=True, retries=1)
    learner = CapacityLearner()
    learned = learner.update(DEFAULT_CF, obs, default=DEFAULT_CF)
    if learner.target(obs, default=DEFAULT_CF) < learner.max_factor:
        assert expert_capacity(T, k, E, learned) >= min(peak, T * k)
