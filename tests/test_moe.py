"""MoE layer: sort-based dispatch exactness vs dense reference, aux loss,
capacity-overflow signalling, stability of per-expert token order."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import (
    MoEConfig,
    moe_apply_ep_replicated,
    moe_init,
    router_probs,
)

def dense_reference(p, cfg, x):
    """Compute the exact MoE output without any dispatch machinery."""
    probs, top_idx, top_gate, _ = router_probs(p, cfg, x)
    T, D = x.shape
    out = np.zeros((T, D), np.float32)
    w_in, w_out = np.asarray(p["w_in"]), np.asarray(p["w_out"])
    w_gate = np.asarray(p["w_gate"]) if "w_gate" in p else None
    xn = np.asarray(x)
    for t in range(T):
        for kk in range(cfg.top_k):
            e = int(top_idx[t, kk])
            h = xn[t] @ w_in[e]
            if w_gate is not None:
                g = xn[t] @ w_gate[e]
                h = (g / (1 + np.exp(-g))) * h
            else:
                h = 0.5 * h * (1 + np.vectorize(np.math.erf)(h / np.sqrt(2)))
            out[t] += float(top_gate[t, kk]) * (h @ w_out[e])
    return out


def test_single_device_moe_matches_dense_reference(key):
    cfg = MoEConfig(d_model=16, d_ff=8, n_experts=4, top_k=2, capacity_factor=8.0)
    p = moe_init(key, cfg, jnp.float32, ep_shards=1)
    x = jax.random.normal(jax.random.PRNGKey(1), (24, 16))
    y, aux, overflow = moe_apply_ep_replicated(p, cfg, x)
    ref = dense_reference(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y), ref, atol=1e-4, rtol=1e-4)
    assert not bool(overflow)
    assert float(aux) > 0


def test_capacity_overflow_signal_and_drop(key):
    """cf tiny -> tokens drop (output changes), overflow flag raised."""
    cfg_big = MoEConfig(d_model=16, d_ff=8, n_experts=4, top_k=2, capacity_factor=8.0)
    cfg_tiny = cfg_big._replace(capacity_factor=0.01)
    p = moe_init(key, cfg_big, jnp.float32, ep_shards=1)
    x = jax.random.normal(jax.random.PRNGKey(2), (32, 16))
    y_full, _, ovf_full = moe_apply_ep_replicated(p, cfg_big, x)
    y_drop, _, ovf_drop = moe_apply_ep_replicated(p, cfg_tiny, x)
    assert not bool(ovf_full)
    assert bool(ovf_drop)
    assert not np.allclose(np.asarray(y_full), np.asarray(y_drop))


def test_router_masks_padding_experts(key):
    """ep_shards=4 with 5 real experts -> table padded to 8; dummies unreachable."""
    cfg = MoEConfig(d_model=16, d_ff=8, n_experts=5, top_k=2)
    p = moe_init(key, cfg, jnp.float32, ep_shards=4)
    assert p["w_in"].shape[0] == 8
    x = jax.random.normal(jax.random.PRNGKey(3), (64, 16))
    probs, top_idx, _, _ = router_probs(p, cfg, x)
    assert int(jnp.max(top_idx)) < 5
    assert np.allclose(np.asarray(probs[:, 5:]), 0.0)


def test_aux_loss_favours_balance(key):
    cfg = MoEConfig(d_model=8, d_ff=4, n_experts=4, top_k=1)
    p = moe_init(key, cfg, jnp.float32, ep_shards=1)
    x = jax.random.normal(jax.random.PRNGKey(4), (256, 8))
    _, _, _, aux_random = router_probs(p, cfg, x)
    # collapse the router to always pick expert 0 -> aux should rise
    p_collapsed = {**p, "router": {"w": jnp.zeros_like(p["router"]["w"]).at[:, 0].set(10.0)}}
    _, _, _, aux_collapsed = router_probs(p_collapsed, cfg, x)
    assert float(aux_collapsed) > float(aux_random)
