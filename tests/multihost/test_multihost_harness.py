"""The harness's own guarantees: crash containment and timeout enforcement.

A wedged or killed rank must fail *that test* with diagnostics, quickly —
never hang the pytest run.  These tests inject the failures deliberately
and time the coordinator's response.
"""
import time

import pytest

import harness

pytestmark = pytest.mark.multihost


def test_killed_rank_fails_cleanly_within_grace_period():
    """Rank 1 dies hard (``os._exit(17)``, no report); rank 0 would sleep for
    two minutes.  The coordinator must fail the run shortly after the grace
    period and terminate the survivor — not wait out the sleep."""
    t0 = time.monotonic()
    run = harness.run_multihost(
        "bodies.py:crash_body", 2, args={"victim": 1}, timeout=60
    )
    elapsed = time.monotonic() - t0
    assert not run.ok
    assert not run.timed_out, "a crash is a failure, not a timeout"
    assert run.reports[1].returncode == 17
    assert "rc=17" in run.reports[1].error
    assert run.reports[0].returncode != 0, "survivor must have been terminated"
    assert elapsed < harness.GRACE_AFTER_FAILURE_S + 30, (
        f"containment took {elapsed:.0f}s — survivor was not reaped promptly"
    )
    # per-rank diagnostics are available for the failure message
    assert "rank 1: FAILED" in run.describe()
    # every report — including the one the coordinator synthesized for the
    # rank that died before writing anything — satisfies the report schema
    for r in run.reports:
        doc = harness.validate_report_doc(r.to_doc())
        assert doc["rank"] == r.rank
    assert run.reports[1].result is None


def test_hung_run_is_killed_at_timeout():
    t0 = time.monotonic()
    run = harness.run_multihost("bodies.py:hang_body", 2, timeout=10)
    elapsed = time.monotonic() - t0
    assert not run.ok
    assert run.timed_out
    assert elapsed < 40, f"timeout enforcement took {elapsed:.0f}s"
    assert all(not r.ok for r in run.reports)
    assert "timeout" in run.reports[0].error


def test_failed_rank_report_carries_traceback():
    """A body that raises produces a per-rank report with the traceback —
    the coordinator surfaces *why*, not just that a rank failed."""
    run = harness.run_multihost(
        "bodies.py:cluster_sort_body", 1, args={"n": 64, "mode": "nonsense"}
    )
    assert not run.ok
    r = run.reports[0]
    assert r.returncode == 1
    assert r.error and r.traceback
    assert "nonsense" in (r.traceback or "") or "nonsense" in (r.error or "")


def test_require_success_message_names_the_failing_rank():
    run = harness.run_multihost(
        "bodies.py:crash_body", 2, args={"victim": 0}, timeout=60
    )
    with pytest.raises(AssertionError, match="rank 0: FAILED"):
        run.require_success()


# ------------------------------------------------------- report schema ---
def test_report_schema_validator_rejects_malformed():
    """The schema contract, pinned negatively: every way a report can rot
    raises with a message naming the violation."""
    good = {"rank": 0, "ok": True, "result": {"x": 1}, "error": None}
    assert harness.validate_report_doc(good) is good
    bad = [
        ([1, 2], "must be an object"),
        ({"rank": 0, "ok": True}, "missing fields"),
        ({**good, "rank": -1}, "non-negative"),
        ({**good, "rank": True}, "non-negative int"),
        ({**good, "ok": 1}, "must be a bool"),
        ({**good, "error": 5}, "null or a string"),
        ({**good, "traceback": 5}, "null or a string"),
        ({**good, "duration_s": "3s"}, "null or a number"),
        ({**good, "returncode": "0"}, "null or an int"),
        ({**good, "ok": False}, "must carry an error"),
        ({**good, "result": {1, 2}}, "not JSON-serializable"),
    ]
    for doc, msg in bad:
        with pytest.raises(ValueError, match=msg):
            harness.validate_report_doc(doc)


def test_on_disk_reports_are_schema_valid():
    """What ranks actually write (``_worker.py``) satisfies the same schema
    the coordinator's synthesized reports do — ok and failed alike."""
    import json
    import os

    ok_run = harness.run_multihost(
        "bodies.py:cluster_sort_body", 1, args={"n": 64, "seed": 3}
    ).require_success()
    failed_run = harness.run_multihost(
        "bodies.py:cluster_sort_body", 1, args={"n": 64, "mode": "nonsense"}
    )
    assert not failed_run.ok
    for run, want_ok in ((ok_run, True), (failed_run, False)):
        path = os.path.join(run.report_dir, "report-0.json")
        with open(path) as f:
            doc = harness.validate_report_doc(json.load(f))
        assert doc["ok"] is want_ok
        if not want_ok:
            assert "nonsense" in (doc["error"] or "") + (doc["traceback"] or "")
