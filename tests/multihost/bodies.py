"""Body functions the multihost harness runs inside every rank process.

Each body takes a ``MultihostContext`` (rank, nprocs, args, mesh/array
helpers) and returns a JSON-serializable report.  Bodies must be
deterministic functions of ``ctx.args`` — every rank builds the same host
data from the shared seed, and the same body run on the single-process
forced mesh (``harness.run_forced_mesh``) must produce the identical
report, which is exactly what the bit-identity tests assert.

Loaded by file path in ``_worker.py`` — keep this module import-light at
top level (jax is imported inside bodies, after the worker pinned the
platform and device count).
"""
from __future__ import annotations

import hashlib
import os
import time


def _sha(arr) -> str:
    import numpy as np

    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()


# ----------------------------------------------------------- cluster sort ---
def cluster_sort_body(ctx):
    """Model-D cluster_sort across the whole job; returns the sorted output.

    Asserts correctness against ``np.sort`` in-process; the coordinator
    additionally asserts bit-identity across ranks and against the
    single-process forced-mesh run.
    """
    import jax
    import numpy as np

    from repro.core.cluster_sort import cluster_sort
    from repro.engine.planner import mesh_fingerprint, parse_plan_key, plan_key
    import jax.numpy as jnp

    a = ctx.args
    n, seed, mode = a.get("n", 256), a.get("seed", 0), a.get("mode", "splitters")
    hi = 1 << 20
    rng = np.random.default_rng(seed)
    x_np = rng.integers(0, hi, size=n).astype(np.int32)
    mesh = ctx.mesh()
    x = ctx.global_array(x_np, mesh)
    kwargs = {"mode": mode}
    if mode == "range":
        kwargs.update(lo=0, hi=hi)
    slab, valid = cluster_sort(x, mesh, "x", **kwargs)
    slab_g = ctx.allgather(slab)
    valid_g = ctx.allgather(valid).astype(bool)
    got = slab_g[valid_g]
    assert np.array_equal(got, np.sort(x_np)), "cluster_sort output wrong"

    # the fingerprint round-trips through plan keys on this topology
    fp = mesh_fingerprint(mesh)
    key = plan_key(n, jnp.int32, mesh)
    bucket, dtype_name, parsed_fp = parse_plan_key(key)
    assert (dtype_name, parsed_fp) == ("int32", fp) and bucket >= n
    return {
        "processes": jax.process_count(),
        "local_devices": jax.local_device_count(),
        "devices": jax.device_count(),
        "mesh_fp": fp,
        "local_fp": mesh_fingerprint(None),
        "sorted": got.tolist(),
    }


def cluster_sort_kv_body(ctx):
    """Stable key-value cluster sort: payloads ride the exchange exactly."""
    import numpy as np

    from repro.engine.kv import cluster_sort_kv

    a = ctx.args
    n, seed = a.get("n", 256), a.get("seed", 0)
    rng = np.random.default_rng(seed)
    # few distinct keys -> heavy duplicates, so stability does real work
    k_np = rng.integers(0, 32, size=n).astype(np.int32)
    idx_np = np.arange(n, dtype=np.int32)
    w_np = rng.standard_normal(n).astype(np.float32)
    mesh = ctx.mesh()
    keys = ctx.global_array(k_np, mesh)
    values = {
        "idx": ctx.global_array(idx_np, mesh),
        "w": ctx.global_array(w_np, mesh),
    }
    slab_k, slab_v, valid = cluster_sort_kv(keys, values, mesh, "x")
    valid_g = ctx.allgather(valid).astype(bool)
    got_k = ctx.allgather(slab_k)[valid_g]
    got_idx = ctx.allgather(slab_v["idx"])[valid_g]
    got_w = ctx.allgather(slab_v["w"])[valid_g]

    order = np.argsort(k_np, kind="stable")
    assert np.array_equal(got_k, k_np[order]), "keys not sorted"
    assert np.array_equal(got_idx, order.astype(np.int32)), "not stable"
    assert np.array_equal(got_w, w_np[order]), "payload misaligned"
    return {
        "sorted_keys": got_k.tolist(),
        "idx": got_idx.tolist(),
        "w_sha": _sha(got_w),
    }


# -------------------------------------------------------------- wire layer ---
def exchange_roundtrip_body(ctx):
    """partition_exchange -> combine_exchange round trip, plain and int8 wire."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    import repro  # noqa: F401  (jax compat shims)
    from jax.sharding import PartitionSpec as P

    from repro.exchange import combine_exchange, partition_exchange

    a = ctx.args
    seed, d = a.get("seed", 0), a.get("d", 4)
    mesh = ctx.mesh()
    P_ = mesh.shape["x"]
    m = a.get("m", 32)                      # per-shard elements
    n = m * P_
    rng = np.random.default_rng(seed)
    k_np = rng.integers(0, P_, size=n).astype(np.int32)   # bucket == dest shard
    v_np = rng.standard_normal((n, d)).astype(np.float32)
    i_np = np.arange(n, dtype=np.int32)

    keys = ctx.global_array(k_np, mesh)
    vals = ctx.global_array(v_np, mesh)
    ids = ctx.global_array(i_np, mesh)

    def roundtrip(compress):
        def body(k, v, i):
            ex = partition_exchange(
                k, {"v": v, "i": i}, k, "x", capacity=m, compress=compress
            )
            back = combine_exchange(ex.recv_values, ex, "x")
            return back["v"], back["i"], ex.overflow

        f = jax.jit(
            jax.shard_map(
                body,
                mesh=mesh,
                in_specs=(P("x"), P("x"), P("x")),
                out_specs=(P("x"), P("x"), P()),
            )
        )
        bv, bi, ovf = f(keys, vals, ids)
        return ctx.allgather(bv), ctx.allgather(bi), bool(np.asarray(ovf))

    bv, bi, ovf = roundtrip(False)
    assert not ovf
    assert np.array_equal(bv, v_np), "uncompressed payload must round-trip exactly"
    assert np.array_equal(bi, i_np)

    qv, qi, qovf = roundtrip(True)                        # the int8 wire
    assert not qovf
    assert np.array_equal(qi, i_np), "integer leaves must never be quantized"
    # int8 + per-row scale: bounded relative error, bit-exact determinism
    scale = np.maximum(np.abs(v_np).max(axis=-1, keepdims=True) / 127.0, 1e-12)
    assert np.all(np.abs(qv - v_np) <= 0.5 * scale + 1e-6), "int8 wire error bound"
    return {"plain_sha": _sha(bv), "int8_sha": _sha(qv), "ids_sha": _sha(qi)}


# ---------------------------------------------------------------- MoE layer ---
def moe_adaptive_body(ctx):
    """moe_apply_adaptive learning expert capacity into a *shared* plan file.

    Every rank runs the replicated adaptive MoE forward on identical skewed
    tokens with a planner backed by the same ``plans_path`` — the
    concurrent-writer scenario the fcntl-locked merge-save exists for.
    """
    import jax
    import jax.numpy as jnp

    from repro.engine.planner import Planner
    from repro.models.moe import (
        MoEConfig,
        collapse_router,
        moe_apply_adaptive,
        moe_init,
        moe_plan_key,
    )

    a = ctx.args
    planner = Planner(a["plans_path"], learned_scope=a.get("scope", "global"))
    cfg = MoEConfig(
        d_model=8, d_ff=16, n_experts=4, top_k=2, capacity_factor=1.0,
        mlp_gated=False,
    )
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32, ep_shards=1)
    p = collapse_router(p)                    # worst-case routing skew
    x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model), jnp.float32)
    y, aux, counts = moe_apply_adaptive(p, cfg, x, planner=planner)
    planner.save()
    key = moe_plan_key(x.shape[0], cfg, x.dtype)
    factor = planner.capacity_factor_for(key, default=cfg.capacity_factor)
    assert factor > cfg.capacity_factor, "skew must have raised the factor"
    return {
        "y_sha": _sha(y),
        "counts": [int(c) for c in counts],
        "plan_key": key,
        "scoped_key": planner.scoped_key(key),
        "learned_factor": factor,
        "learned_keys": sorted(planner.learned),
    }


# ----------------------------------------------------- concurrent learning ---
def sort_learn_body(ctx):
    """Skewed model-D sort with the full capacity-learning loop active,
    persisting into one shared plan-cache file from every rank at once."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core.cluster_sort import cluster_sort
    from repro.engine.planner import Planner, plan_key

    a = ctx.args
    planner = Planner(a["plans_path"], learned_scope=a.get("scope", "global"))
    n, seed = a.get("n", 256), a.get("seed", 0)
    hi = 1 << 20
    rng = np.random.default_rng(seed)
    # every key in the bottom 1/64 of the range: range-mode bucket 0 is hot
    x_np = rng.integers(0, hi // 64, size=n).astype(np.int32)
    mesh = ctx.mesh()
    x = ctx.global_array(x_np, mesh)
    # mode= is passed explicitly below, so hint it to cluster_kwargs: a
    # skew-promoted cell must not inject a second "mode" key into kwargs
    kwargs = planner.cluster_kwargs(n, jnp.int32, mesh, mode="range")
    slab, valid = cluster_sort(x, mesh, "x", mode="range", lo=0, hi=hi, **kwargs)
    got = ctx.allgather(slab)[ctx.allgather(valid).astype(bool)]
    assert np.array_equal(got, np.sort(x_np))
    planner.save()
    key = plan_key(n, jnp.int32, mesh)
    return {
        "plan_key": key,
        "scoped_key": planner.scoped_key(key),
        "learned_factor": planner.capacity_factor_for(key),
        "learned_keys": sorted(planner.learned),
    }


def skew_promotion_body(ctx):
    """The radix->sample auto-promotion loop across a real multi-process mesh.

    Every rank serves the same persistently skewed (Zipfian) keys through the
    planner's capacity-learning loop against one shared plan-cache file: the
    cell starts on the radix partition, accrues skew strikes, latches to the
    sample partition, and a fresh planner over the same file (the simulated
    restart) comes back already promoted.  The per-step (mode, retries,
    ratio) trace is returned so the coordinator can assert the multi-process
    trajectory is bit-identical to the single-process forced-mesh one.
    """
    import jax.numpy as jnp
    import numpy as np

    from repro.core.cluster_sort import cluster_sort
    from repro.engine.adapt import CapacityLearner
    from repro.engine.planner import Planner, plan_key

    a = ctx.args
    planner = Planner(a["plans_path"], learned_scope=a.get("scope", "global"))
    # a 2-shard mesh has only 2 buckets, so peak/mean tops out at exactly
    # 2.0 — the default promote_ratio can never be *exceeded* there; small
    # topologies lower the threshold (an operator knob, not a test cheat)
    if "promote_ratio" in a:
        planner.learner = CapacityLearner(promote_ratio=a["promote_ratio"])
    n, seed, steps = a.get("n", 256), a.get("seed", 0), a.get("steps", 5)
    rng = np.random.default_rng(seed)
    x_np = np.minimum(rng.zipf(1.5, n), 1 << 30).astype(np.int32)
    mesh = ctx.mesh()
    x = ctx.global_array(x_np, mesh)
    key = plan_key(n, jnp.int32, mesh)
    want = np.sort(x_np)

    trace = []
    for _ in range(steps):
        kwargs = planner.cluster_kwargs(n, jnp.int32, mesh, default=2.0)
        # un-promoted: no "mode" key -> run the radix family this loop is
        # about; promoted: the planner injected "mode": "sample"
        mode = kwargs.pop("mode", "radix")
        slab, valid = cluster_sort(x, mesh, "x", mode=mode, **kwargs)
        got = ctx.allgather(slab)[ctx.allgather(valid).astype(bool)]
        assert np.array_equal(got, want), f"{mode}-mode sort output wrong"
        obs = planner.telemetry.last(planner.scoped_key(key))
        part, strikes = planner.promotion_state(key)
        trace.append(
            {
                "mode": mode,
                "partition": obs.partition,
                "retries": int(obs.retries),
                "ratio": round(planner.telemetry.last_ratio(planner.scoped_key(key)), 4),
                "promoted": part,
                "strikes": strikes,
            }
        )
    planner.save()

    # simulated restart: a fresh planner over the shared locked plan cache
    # must come back already promoted, and its serving path (cluster_kwargs)
    # must inject the sample mode on the very first call
    p2 = Planner(a["plans_path"], learned_scope=a.get("scope", "global"))
    part2, strikes2 = p2.promotion_state(key)
    return {
        "trace": trace,
        "restart_partition": part2,
        "restart_strikes": strikes2,
        "restart_mode": p2.cluster_kwargs(n, jnp.int32, mesh, default=2.0).get(
            "mode"
        ),
        "sorted": got.tolist(),
    }


# ------------------------------------------------- expert-parallel training ---
def moe_train_step_body(ctx):
    """The between-step MoE capacity loop on a real expert-parallel mesh.

    Runs ``train_step`` for a tiny skewed MoE LM on a 2-D (data=2, model=2)
    mesh spanning every device in the job, with the
    ``MoECapacityController`` reading/writing a shared plan-cache file.
    Parameter updates are discarded between steps so the routing — and with
    it the integer dropped/peak trace and the learned factor — is a
    deterministic function of ``ctx.args`` alone: the same trace must come
    out of a 2-process x 2-device run, a 4-process x 1-device run, and the
    single-process forced mesh (only the plan cell's topology fingerprint
    may differ).  Float loss is *not* bit-comparable across topologies
    (reduction order); it is only checked finite.
    """
    import functools
    from dataclasses import replace

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs.base import ARCHS
    from repro.engine.planner import Planner
    from repro.models.moe import collapse_router
    from repro.models.transformer import ShardCtx, model_init
    from repro.optim.adamw import OptConfig, init_opt_state
    from repro.train.adaptive import MoECapacityController
    from repro.train.steps import train_step

    a = ctx.args
    steps = a.get("steps", 2)
    cfg = replace(
        ARCHS["qwen3-0.6b"], name="moe-mh-tiny",
        n_layers=1, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
        d_ff=32, vocab_size=64, kv_chunk=16,
        pattern=("attn",), ffn_pattern=("moe",),
        n_experts=8, top_k=2, capacity_factor=1.0,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    tctx = ShardCtx(mesh=mesh, axes=("data", "model"))

    # init on the local device, then replicate the host values over the
    # global mesh — every rank computes the identical tree from the seed
    def replicate(tree):
        return jax.tree.map(
            lambda v: jax.device_put(
                np.asarray(v), NamedSharding(mesh, P())
            ),
            tree,
        )

    params = model_init(jax.random.PRNGKey(0), cfg, ep_shards=tctx.ep_shards)
    params["blocks"] = {
        pos: ({**gp, "moe": collapse_router(gp["moe"], 6.0)} if "moe" in gp else gp)
        for pos, gp in params["blocks"].items()
    }
    params = replicate(params)
    ocfg = OptConfig(peak_lr=1e-4, warmup_steps=2, total_steps=max(steps, 2))
    opt = init_opt_state(params, ocfg)

    planner = Planner(a["plans_path"], learned_scope=a.get("scope", "global"))
    batch_sz, seq = 4, 16                 # T = 64 tokens over 4 devices
    ctl = MoECapacityController(
        cfg.moe_cfg(), tokens=batch_sz * seq, ctx=tctx,
        planner=planner, dtype=cfg.compute_dtype,
    )

    @functools.lru_cache(maxsize=None)
    def step_fn(cap):
        return jax.jit(functools.partial(
            train_step, cfg=cfg, opt_cfg=ocfg, ctx=tctx,
            n_microbatch=1, loss_chunk=seq, moe_capacity=cap))

    rng = np.random.default_rng(a.get("seed", 0))
    trace = []
    losses_finite = True
    for _ in range(steps):
        tok = rng.integers(1, cfg.vocab_size, (batch_sz, seq + 1)).astype(np.int32)
        batch = replicate({"tokens": tok[:, :-1], "labels": tok[:, 1:]})
        cap = ctl.capacity
        _, _, m = step_fn(cap)(params, opt, batch)  # updates discarded (see docstring)
        m = {k: float(v) if jnp.ndim(v) == 0 else v for k, v in m.items()}
        ctl.observe(m, capacity=cap)
        trace.append(
            {"cap": cap, "dropped": int(m["moe_dropped"]), "peak": int(m["moe_peak"])}
        )
        losses_finite = losses_finite and bool(np.isfinite(m["loss"]))
    planner.save()

    factor = ctl.factor
    assert factor > cfg.moe_cfg().capacity_factor, "skew must raise the factor"
    return {
        "processes": jax.process_count(),
        "plan_key": ctl.key,
        "scoped_key": planner.scoped_key(ctl.key),
        "learned_factor": factor,
        "trace": trace,
        "losses_finite": losses_finite,
    }


# -------------------------------------------------- distributed autotune ---
def _autotune_candidates():
    """The tiny explicit candidate list the autotune bodies sweep: one
    member of each strategy family that is known-good on a CPU gloo mesh,
    small enough that a 2-proc sweep stays inside a CI smoke budget."""
    from repro.engine.planner import SortPlan

    return [
        SortPlan("shared", local_impl="xla"),
        SortPlan("shared", local_impl="merge"),
        SortPlan("cluster", local_impl="xla", capacity_factor=2.0, mode="splitters"),
        SortPlan("cluster", local_impl="xla", capacity_factor=2.0, mode="sample"),
    ]


def autotune_body(ctx):
    """Rank-coordinated ``Planner.autotune`` over the whole process mesh.

    Every rank sweeps the same explicit candidate list against one shared
    plan-cache file; the distributed path must leave every rank holding the
    same winning plan (broadcast from rank 0), an identical in-memory plan
    table, and — after the post-save barrier — a cache file on disk whose
    tuned cell matches what every rank holds.  ``ctx.maybe_fault`` hooks
    each candidate boundary, so the same body doubles as the fault-injection
    battery (crash/hang mid-sweep).
    """
    import jax
    import jax.numpy as jnp

    from repro.engine.planner import Planner, mesh_fingerprint, plan_key

    a = ctx.args
    n, reps = a.get("n", 256), a.get("reps", 3)
    planner = Planner(a["plans_path"])
    mesh = ctx.mesh()

    def on_candidate(i, cand):
        ctx.maybe_fault(f"candidate:{i}")

    best = planner.autotune(
        n,
        jnp.int32,
        mesh=mesh,
        axis="x",
        reps=reps,
        candidates=_autotune_candidates(),
        on_candidate=on_candidate,
    )
    key = plan_key(n, jnp.int32, mesh)
    # every rank re-reads the shared file the post-save barrier guarantees
    # is on disk; its tuned cell must be what this rank holds in memory
    ondisk = Planner(a["plans_path"]).plans.get(key)
    assert ondisk == best, f"disk {ondisk} != broadcast winner {best}"
    return {
        "processes": jax.process_count(),
        "mesh_fp": mesh_fingerprint(mesh),
        "plan_key": key,
        "best": best.to_dict(),
        "plans": {k: p.to_dict() for k, p in sorted(planner.plans.items())},
        "wrote": planner.last_autotune_wrote,
    }


def autotune_local_body(ctx):
    """Two *uncoordinated* autotuners racing one shared plan cache.

    Each rank opts out of the distributed sweep (``distributed=False`` — its
    cells are rank-divergent, so collectives would deadlock) and tunes a
    rank-specific size bucket of shared-strategy candidates into the same
    file.  The fcntl-locked merge-on-save must union the tables: the final
    file carries every rank's cell.
    """
    import jax.numpy as jnp

    from repro.engine.planner import Planner, SortPlan, plan_key

    a = ctx.args
    n = a.get("base_n", 64) << ctx.rank  # rank-distinct size buckets
    planner = Planner(a["plans_path"])
    cands = [
        SortPlan("shared", local_impl="xla"),
        SortPlan("shared", local_impl="merge"),
    ]
    best = planner.autotune(
        n, jnp.int32, reps=a.get("reps", 2), distributed=False, candidates=cands
    )
    key = plan_key(n, jnp.int32)
    return {
        "plan_key": key,
        "best": best.to_dict(),
        "wrote": planner.last_autotune_wrote,
        "file_keys": sorted(Planner(a["plans_path"]).plans),
    }


def gloo_timing_body(ctx):
    """Time model B (shared) and model D (cluster) on this job's mesh.

    Run under 2-process gloo *and* under the single-process forced mesh with
    the same args, the two reports quantify what the real wire costs: the
    shared row is pure local compute (identical either way), the cluster row
    pays gloo message passing only in the multi-process run.  Timings use
    the planner's own helpers — median of reps, max over ranks — so the
    number is the one a distributed autotune sweep would score.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.engine import planner as planner_mod
    from repro.engine.planner import SortPlan

    a = ctx.args
    n, reps, seed = a.get("n", 4096), a.get("reps", 3), a.get("seed", 0)
    rng = np.random.default_rng(seed)
    x_np = rng.integers(0, 1 << 20, size=n).astype(np.int32)
    mesh = ctx.mesh()
    plans = {
        "shared": (SortPlan("shared", local_impl="xla"), jnp.asarray(x_np)),
        "cluster": (
            SortPlan("cluster", local_impl="xla", capacity_factor=2.0, mode="sample"),
            ctx.global_array(x_np, mesh),
        ),
    }
    out = {}
    for name, (plan, arr) in sorted(plans.items()):
        times = planner_mod._time_plan_reps(plan, arr, mesh, "x", reps=reps)
        us = planner_mod._median(times)
        out[name] = planner_mod._max_over_ranks(us) if ctx.nprocs > 1 else us
    out["devices"] = jax.device_count()
    return out


# --------------------------------------------------------- failure injection ---
def crash_body(ctx):
    """The victim rank dies hard mid-test; survivors sit in a long wait.

    Exercises the harness's crash containment: the coordinator must fail the
    test promptly (victim rc != 0) and terminate the survivors instead of
    letting pytest hang.
    """
    victim = ctx.args.get("victim", 1)
    if ctx.rank == victim:
        os._exit(17)  # no report, no cleanup — as close to a segfault as python gets
    time.sleep(120)
    return {"survived": True}


def hang_body(ctx):
    """Every rank wedges; only the run timeout can end this test."""
    time.sleep(600)
    return {"finished": True}
