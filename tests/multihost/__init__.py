# A real package so pytest imports this directory's conftest as
# ``multihost.conftest`` — a bare conftest.py here would clobber the
# top-level ``tests/conftest`` module name and break its importers.
