"""Real multi-process cluster sort: bit-identical to the single-process mesh.

Every other sort test in the repo runs on a forced single-process
multi-device mesh.  These run the same bodies across genuinely separate
``jax.distributed`` processes (gloo CPU collectives) and assert the output
is not just correct but **bit-identical** to the forced-mesh reference —
the distributed exchange must be a pure re-plumbing of the same math.
"""
import pytest

import harness

pytestmark = pytest.mark.multihost


def test_cluster_sort_2proc_bit_identical_to_forced():
    args = {"n": 256, "seed": 3, "mode": "splitters"}
    multi = harness.run_multihost(
        "bodies.py:cluster_sort_body", 2, args=args
    ).require_success()
    forced = harness.run_forced_mesh(
        "bodies.py:cluster_sort_body", 2, args=args
    ).require_success()
    r0, r1 = multi.results()
    assert r0["sorted"] == r1["sorted"], "ranks disagree on the sorted output"
    assert r0["sorted"] == forced.result()["sorted"], (
        "2-process sort differs from the single-process 2-device reference"
    )
    assert r0["processes"] == 2 and r0["devices"] == 2


def test_cluster_sort_range_mode_2proc_bit_identical_to_forced():
    args = {"n": 300, "seed": 11, "mode": "range"}
    multi = harness.run_multihost(
        "bodies.py:cluster_sort_body", 2, args=args
    ).require_success()
    forced = harness.run_forced_mesh(
        "bodies.py:cluster_sort_body", 2, args=args
    ).require_success()
    assert multi.result()["sorted"] == forced.result()["sorted"]


def test_cluster_sort_4proc():
    args = {"n": 512, "seed": 7, "mode": "splitters"}
    multi = harness.run_multihost(
        "bodies.py:cluster_sort_body", 4, args=args
    ).require_success()
    results = multi.results()
    assert all(r["sorted"] == results[0]["sorted"] for r in results)
    assert results[0]["devices"] == 4
    forced = harness.run_forced_mesh(
        "bodies.py:cluster_sort_body", 4, args=args
    ).require_success()
    assert results[0]["sorted"] == forced.result()["sorted"]


def test_2x2_topology_distinct_fingerprint():
    """2 processes x 2 devices: same global device count as forced 4-device,
    but the plan-cache fingerprint must tell the topologies apart."""
    args = {"n": 256, "seed": 9, "mode": "splitters"}
    multi = harness.run_multihost(
        "bodies.py:cluster_sort_body", 2, args=args, local_devices=2
    ).require_success()
    forced = harness.run_forced_mesh(
        "bodies.py:cluster_sort_body", 4, args=args
    ).require_success()
    r, f = multi.result(), forced.result()
    assert r["devices"] == 4 and r["local_devices"] == 2
    assert r["sorted"] == f["sorted"]
    assert r["mesh_fp"].endswith("/procs2x2")
    assert f["mesh_fp"] == "cpu/x=4"
    assert r["mesh_fp"] != f["mesh_fp"], (
        "a 2x2 multi-process mesh must not share plan-cache cells with a "
        "single-process 4-device mesh"
    )
    assert r["local_fp"].endswith("/procs2x2")


def test_cluster_sort_kv_2proc_bit_identical_to_forced():
    args = {"n": 200, "seed": 5}
    multi = harness.run_multihost(
        "bodies.py:cluster_sort_kv_body", 2, args=args
    ).require_success()
    forced = harness.run_forced_mesh(
        "bodies.py:cluster_sort_kv_body", 2, args=args
    ).require_success()
    r, f = multi.result(), forced.result()
    assert r["sorted_keys"] == f["sorted_keys"]
    assert r["idx"] == f["idx"], "stability order differs across process counts"
    assert r["w_sha"] == f["w_sha"], "float payload not bit-identical"
