"""Per-rank entry point for the multihost harness (never imported by pytest).

Runs in a fresh interpreter per rank: pins the CPU platform and device
count *before* jax initializes, joins the ``jax.distributed`` coordination
service (gloo CPU collectives), loads the body function by file path, runs
it with a ``MultihostContext``, and writes one JSON report atomically.  Any
exception — including a failed distributed init — still produces a report,
so the coordinator can show *why* a rank failed instead of just that it did.
"""
from __future__ import annotations

import argparse
import importlib.util
import json
import os
import sys
import time
import traceback


class MultihostContext:
    """What a body function gets: identity plus the common SPMD plumbing.

    Bodies run once per rank with identical ``args``; jax is imported and
    (for ``nprocs > 1``) ``jax.distributed`` is already initialized by the
    time the body runs.
    """

    def __init__(self, rank: int, nprocs: int, args: dict):
        self.rank = rank
        self.nprocs = nprocs
        self.args = args

    def mesh(self, axis: str = "x"):
        """1-D mesh over every device in the job (all processes)."""
        import jax

        return jax.make_mesh((jax.device_count(),), (axis,))

    def global_array(self, host_array, mesh, axis: str = "x"):
        """Shard a host-replicated array over ``mesh[axis]``.

        Every rank passes the same full value (deterministic from the shared
        seed in ``args``); each process places only its addressable shards.
        """
        import jax
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec

        return jax.device_put(
            jnp.asarray(host_array), NamedSharding(mesh, PartitionSpec(axis))
        )

    def allgather(self, x):
        """Gather a sharded array to a host numpy array on every rank."""
        import numpy as np
        from jax.experimental import multihost_utils

        return np.asarray(multihost_utils.process_allgather(x, tiled=True))

    def maybe_fault(self, point: str) -> None:
        """Fault-injection hook: trigger the configured fault at ``point``.

        Bodies sprinkle ``ctx.maybe_fault("...")`` at interesting spots;
        ``args["fault"] = {"rank": r, "point": p, "kind": ...}`` arms exactly
        one of them on exactly one rank.  ``kind="crash"`` exits hard
        (``os._exit``, no report, no distributed shutdown — as close to a
        segfault as python gets); ``kind="hang"`` sleeps far past any test
        timeout, wedging whatever collective the peers are blocked in.
        Unarmed ranks and unmatched points are no-ops, so the same body
        runs faulted and fault-free.
        """
        fault = self.args.get("fault")
        if not fault or fault.get("rank") != self.rank:
            return
        if fault.get("point") != point:
            return
        if fault.get("kind", "crash") == "crash":
            os._exit(int(fault.get("exit_code", 13)))
        time.sleep(float(fault.get("sleep_s", 600.0)))


def load_body(spec: str):
    """``"<file.py>:<function>"`` -> callable, file relative to this dir.

    Loaded by path (not import) so neither ``tests`` nor ``tests.multihost``
    needs to be a package.
    """
    fname, _, func = spec.partition(":")
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)), fname)
    mod_spec = importlib.util.spec_from_file_location("_multihost_bodies", path)
    mod = importlib.util.module_from_spec(mod_spec)
    mod_spec.loader.exec_module(mod)
    return getattr(mod, func)


def write_report(path: str, doc: dict) -> None:
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=1)
    os.replace(tmp, path)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--spec", required=True)
    ap.add_argument("--rank", type=int, required=True)
    ap.add_argument("--nprocs", type=int, required=True)
    ap.add_argument("--coordinator", required=True)
    ap.add_argument("--report", required=True)
    ap.add_argument("--local-devices", type=int, default=1)
    ap.add_argument("--args-json", default="{}")
    ns = ap.parse_args()

    # platform + device count are fixed at first jax import; set them first
    os.environ["JAX_PLATFORMS"] = "cpu"
    if ns.local_devices > 1:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={ns.local_devices} "
            + os.environ.get("XLA_FLAGS", "")
        ).strip()

    t0 = time.monotonic()
    doc = {"rank": ns.rank, "ok": False, "result": None, "error": None}
    try:
        import jax

        if ns.nprocs > 1:
            jax.config.update("jax_cpu_collectives_implementation", "gloo")
            jax.distributed.initialize(
                coordinator_address=ns.coordinator,
                num_processes=ns.nprocs,
                process_id=ns.rank,
            )
        body = load_body(ns.spec)
        ctx = MultihostContext(ns.rank, ns.nprocs, json.loads(ns.args_json))
        doc["result"] = body(ctx)
        doc["ok"] = True
    except BaseException as e:  # report even SystemExit-ish failures
        doc["error"] = repr(e)
        doc["traceback"] = traceback.format_exc()
    doc["duration_s"] = round(time.monotonic() - t0, 3)
    write_report(ns.report, doc)
    return 0 if doc["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
