"""Coordinator for real multi-process ``jax.distributed`` tests.

Every multi-device test elsewhere in this repo runs on a *single-process*
forced multi-device mesh (``tests/conftest.run_with_devices``), which can
never exercise cross-process behaviour: real inter-node collectives, per-host
state, concurrent plan-cache writers.  This harness launches N genuinely
separate Python processes, each calling ``jax.distributed.initialize``
against a shared coordinator, runs one *body* function in every process, and
collects per-rank JSON reports back over files.

Design (modeled on pytest-isolated-style subprocess grouping):

* **Isolation** — the pytest process never initializes ``jax.distributed``
  (nor multiple devices); every run gets a fresh set of interpreters, so no
  test can leak distributed state into another.
* **Crash containment** — a rank that dies (segfault, ``os._exit``, OOM
  kill) would normally wedge the surviving ranks inside a collective
  forever.  The coordinator polls; after one rank fails it gives the rest
  ``GRACE_AFTER_FAILURE_S`` to finish, then terminates them.  A hung run is
  killed at ``timeout`` seconds.  Either way the *test* fails with per-rank
  diagnostics — the pytest run itself never hangs.
* **Reports** — each rank writes ``report-<rank>.json`` atomically
  (tmp + ``os.replace``); schema in docs/testing.md.  Set
  ``$REPRO_MULTIHOST_REPORT_DIR`` to keep reports (CI uploads them on
  failure); otherwise they land in a throwaway tempdir.

The single-process reference path lives here too: ``run_forced_mesh`` runs
the *same body* in one process with ``--xla_force_host_platform_device_count``
— the mesh the rest of the test suite uses — so tests can assert the
multi-process path agrees bit-for-bit with the single-process one.
"""
from __future__ import annotations

import json
import os
import socket
import subprocess
import sys
import tempfile
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

MULTIHOST_DIR = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.dirname(os.path.dirname(MULTIHOST_DIR))
WORKER = os.path.join(MULTIHOST_DIR, "_worker.py")

DEFAULT_TIMEOUT_S = 240.0
# once one rank has failed, how long the surviving ranks get to exit on
# their own before the coordinator terminates them (they are usually stuck
# in a collective whose peer no longer exists)
GRACE_AFTER_FAILURE_S = 8.0
_STDIO_TAIL = 4000  # chars of stdout/stderr kept per rank in the report


def free_port() -> int:
    """An OS-assigned free TCP port for the jax.distributed coordinator."""
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def validate_report_doc(doc: Any) -> dict:
    """Validate one per-rank report document against the report schema.

    The contract every report satisfies — whether a rank wrote it itself
    (``_worker.py``) or the coordinator synthesized it for a rank that died
    before writing: ``rank``/``ok``/``result``/``error`` always present,
    types as documented in docs/testing.md, a failed report always carries
    an error string, and the whole document round-trips as JSON.  Returns
    the document; raises ``ValueError`` on any violation.
    """
    if not isinstance(doc, dict):
        raise ValueError(f"report must be an object, got {type(doc).__name__}")
    missing = {"rank", "ok", "result", "error"} - set(doc)
    if missing:
        raise ValueError(f"report missing fields: {sorted(missing)}")
    rank = doc["rank"]
    if not isinstance(rank, int) or isinstance(rank, bool) or rank < 0:
        raise ValueError(f"rank must be a non-negative int: {rank!r}")
    if not isinstance(doc["ok"], bool):
        raise ValueError(f"ok must be a bool: {doc['ok']!r}")
    for key in ("error", "traceback"):
        if doc.get(key) is not None and not isinstance(doc[key], str):
            raise ValueError(f"{key} must be null or a string: {doc[key]!r}")
    dur = doc.get("duration_s")
    if dur is not None and (isinstance(dur, bool) or not isinstance(dur, (int, float))):
        raise ValueError(f"duration_s must be null or a number: {dur!r}")
    rc = doc.get("returncode")
    if rc is not None and (isinstance(rc, bool) or not isinstance(rc, int)):
        raise ValueError(f"returncode must be null or an int: {rc!r}")
    if not doc["ok"] and doc["error"] is None:
        raise ValueError("a failed report must carry an error")
    try:
        json.dumps(doc)
    except (TypeError, ValueError) as e:
        raise ValueError(f"report is not JSON-serializable: {e}") from None
    return doc


@dataclass
class RankReport:
    """One rank's outcome: its JSON report plus process-level diagnostics."""

    rank: int
    ok: bool
    result: Any = None
    error: Optional[str] = None
    traceback: Optional[str] = None
    returncode: Optional[int] = None
    duration_s: Optional[float] = None
    stdout: str = ""
    stderr: str = ""

    def to_doc(self) -> dict:
        """This report as a schema-valid JSON document — identical shape for
        ranks that reported themselves and ranks the coordinator had to
        synthesize after they died (stdio tails are diagnostics, not part
        of the schema)."""
        return {
            "rank": self.rank,
            "ok": self.ok,
            "result": self.result,
            "error": self.error,
            "traceback": self.traceback,
            "returncode": self.returncode,
            "duration_s": self.duration_s,
        }

    def summary(self) -> str:
        status = "ok" if self.ok else f"FAILED (rc={self.returncode})"
        lines = [f"rank {self.rank}: {status}"]
        if self.error:
            lines.append(f"  error: {self.error}")
        if self.traceback:
            lines.append("  " + self.traceback.strip().replace("\n", "\n  "))
        if not self.ok and self.stderr:
            lines.append("  stderr tail:")
            lines.append("  " + self.stderr.strip().replace("\n", "\n  "))
        return "\n".join(lines)


@dataclass
class MultihostRun:
    """Everything one ``run_multihost`` call produced."""

    nprocs: int
    reports: List[RankReport] = field(default_factory=list)
    timed_out: bool = False
    wall_s: float = 0.0
    report_dir: str = ""

    @property
    def ok(self) -> bool:
        return (
            not self.timed_out
            and len(self.reports) == self.nprocs
            and all(r.ok for r in self.reports)
        )

    def result(self, rank: int = 0) -> Any:
        """The body's return value on ``rank`` (requires that rank succeeded)."""
        report = self.reports[rank]
        assert report.ok, self.describe()
        return report.result

    def results(self) -> List[Any]:
        return [self.result(r) for r in range(self.nprocs)]

    def describe(self) -> str:
        head = (
            f"multihost run: nprocs={self.nprocs} ok={self.ok} "
            f"timed_out={self.timed_out} wall={self.wall_s:.1f}s "
            f"reports in {self.report_dir}"
        )
        return "\n".join([head] + [r.summary() for r in self.reports])

    def require_success(self) -> "MultihostRun":
        assert self.ok, self.describe()
        return self


def _worker_env(env: Optional[Dict[str, str]], local_devices: int) -> Dict[str, str]:
    out = dict(os.environ)
    # the worker owns device-count policy; inherited XLA_FLAGS (e.g. from a
    # forced-device pytest wrapper) must not leak into rank processes
    out.pop("XLA_FLAGS", None)
    out["JAX_PLATFORMS"] = "cpu"
    out["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + out["PYTHONPATH"] if out.get("PYTHONPATH") else ""
    )
    if env:
        out.update(env)
    return out


def _read_tail(path: str) -> str:
    try:
        with open(path, errors="replace") as f:
            return f.read()[-_STDIO_TAIL:]
    except OSError:
        return ""


def _terminate(procs: List[subprocess.Popen]) -> None:
    for p in procs:
        if p.poll() is None:
            p.terminate()
    deadline = time.monotonic() + 3.0
    for p in procs:
        if p.poll() is None:
            try:
                p.wait(timeout=max(0.1, deadline - time.monotonic()))
            except subprocess.TimeoutExpired:
                p.kill()
                p.wait()


def run_multihost(
    spec: str,
    nprocs: int,
    *,
    args: Optional[dict] = None,
    timeout: float = DEFAULT_TIMEOUT_S,
    local_devices: int = 1,
    env: Optional[Dict[str, str]] = None,
) -> MultihostRun:
    """Run body ``spec`` (``"<file.py>:<function>"``, file relative to this
    directory) in ``nprocs`` real ``jax.distributed`` processes.

    Each rank sees ``local_devices`` CPU devices (via
    ``--xla_force_host_platform_device_count``), so the global mesh has
    ``nprocs * local_devices`` devices — a 2-process x 2-device run models a
    2-node multi-GPU topology on one machine.  Returns a ``MultihostRun``;
    call ``require_success()`` for an assert with per-rank diagnostics.

    ``nprocs=1`` skips ``jax.distributed.initialize`` entirely — that is the
    single-process reference mode ``run_forced_mesh`` wraps.
    """
    base = os.environ.get("REPRO_MULTIHOST_REPORT_DIR")
    if base:
        os.makedirs(base, exist_ok=True)
        report_dir = tempfile.mkdtemp(prefix="run-", dir=base)
    else:
        report_dir = tempfile.mkdtemp(prefix="repro-multihost-")
    port = free_port()
    wenv = _worker_env(env, local_devices)

    procs: List[subprocess.Popen] = []
    stdio: List[tuple] = []
    t0 = time.monotonic()
    for rank in range(nprocs):
        cmd = [
            sys.executable,
            WORKER,
            "--spec", spec,
            "--rank", str(rank),
            "--nprocs", str(nprocs),
            "--coordinator", f"127.0.0.1:{port}",
            "--report", os.path.join(report_dir, f"report-{rank}.json"),
            "--local-devices", str(local_devices),
        ]
        if args is not None:
            cmd += ["--args-json", json.dumps(args)]
        out_path = os.path.join(report_dir, f"stdout-{rank}.log")
        err_path = os.path.join(report_dir, f"stderr-{rank}.log")
        out_f, err_f = open(out_path, "w"), open(err_path, "w")
        stdio.append((out_path, err_path, out_f, err_f))
        procs.append(
            subprocess.Popen(cmd, env=wenv, stdout=out_f, stderr=err_f, cwd=REPO)
        )

    # --- poll until everyone exits, a failure drains the grace period, or
    #     the deadline lands; never block pytest indefinitely ---
    deadline = t0 + timeout
    first_failure: Optional[float] = None
    timed_out = False
    while True:
        codes = [p.poll() for p in procs]
        if all(c is not None for c in codes):
            break
        now = time.monotonic()
        if now >= deadline:
            timed_out = True
            _terminate(procs)
            break
        if first_failure is None and any(c not in (None, 0) for c in codes):
            first_failure = now
        if first_failure is not None and now - first_failure > GRACE_AFTER_FAILURE_S:
            _terminate(procs)
            break
        time.sleep(0.05)
    wall = time.monotonic() - t0

    run = MultihostRun(
        nprocs=nprocs, timed_out=timed_out, wall_s=wall, report_dir=report_dir
    )
    for rank, p in enumerate(procs):
        out_path, err_path, out_f, err_f = stdio[rank]
        out_f.close()
        err_f.close()
        report = RankReport(
            rank=rank,
            ok=False,
            returncode=p.poll(),
            stdout=_read_tail(out_path),
            stderr=_read_tail(err_path),
        )
        rpath = os.path.join(report_dir, f"report-{rank}.json")
        if os.path.exists(rpath):
            try:
                with open(rpath) as f:
                    doc = validate_report_doc(json.load(f))
                report.ok = bool(doc.get("ok")) and p.poll() == 0
                report.result = doc.get("result")
                report.error = doc.get("error")
                report.traceback = doc.get("traceback")
                report.duration_s = doc.get("duration_s")
                if not report.ok and report.error is None:
                    # the rank said ok but its process still died (rc != 0)
                    report.error = f"rank reported ok but exited rc={p.poll()}"
            except Exception as e:  # unreadable/invalid report = failed rank
                report.error = f"unreadable report: {e!r}"
        elif timed_out:
            report.error = f"no report: run exceeded {timeout:.0f}s timeout"
        elif p.poll() not in (0, None):
            report.error = f"process died with rc={p.poll()} before reporting"
        else:
            report.error = "process exited without writing a report"
        run.reports.append(report)
    return run


def run_forced_mesh(
    spec: str, devices: int, *, args: Optional[dict] = None, timeout: float = DEFAULT_TIMEOUT_S
) -> MultihostRun:
    """The single-process reference: same body, one process, ``devices``
    forced host devices — the mesh every other test in this repo uses.
    Comparing its report against ``run_multihost``'s proves the real
    multi-process path computes the identical answer."""
    return run_multihost(
        spec, nprocs=1, args=args, timeout=timeout, local_devices=devices
    )
