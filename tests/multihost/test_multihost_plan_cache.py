"""Concurrent capacity learning across real processes, one plan-cache file.

The acceptance bar for the planner work in this PR: two ranks running the
full sort capacity-learning loop at the same time against the same JSON
file must produce a *merged* learned section — per-host cells both present
under ``per_host`` scope, a single converged cell under ``global`` scope —
never a last-writer-wins clobber.
"""
import json
import os

import pytest

import harness

pytestmark = pytest.mark.multihost


def _load(plans_path):
    with open(plans_path) as f:
        return json.load(f)


def test_per_host_scope_merges_both_hosts_cells(tmp_path):
    plans_path = os.path.join(str(tmp_path), "plans.json")
    run = harness.run_multihost(
        "bodies.py:sort_learn_body",
        2,
        args={"plans_path": plans_path, "scope": "per_host", "n": 256, "seed": 0},
    ).require_success()
    r0, r1 = run.results()
    assert r0["plan_key"] == r1["plan_key"]
    assert r0["scoped_key"].endswith("@h0")
    assert r1["scoped_key"].endswith("@h1")
    # skewed range-mode traffic forced the learner above the default
    assert r0["learned_factor"] > 2.0
    assert r0["learned_factor"] == r1["learned_factor"]

    doc = _load(plans_path)
    assert sorted(doc["learned"]) == sorted({r0["scoped_key"], r1["scoped_key"]}), (
        "both hosts' learned cells must survive concurrent saves"
    )
    for key in (r0["scoped_key"], r1["scoped_key"]):
        assert doc["learned"][key]["capacity_factor"] == r0["learned_factor"]


def test_global_scope_converges_to_one_merged_cell(tmp_path):
    plans_path = os.path.join(str(tmp_path), "plans.json")
    run = harness.run_multihost(
        "bodies.py:sort_learn_body",
        2,
        args={"plans_path": plans_path, "scope": "global", "n": 256, "seed": 0},
    ).require_success()
    r0, r1 = run.results()
    assert r0["scoped_key"] == r0["plan_key"], "global scope adds no host suffix"
    doc = _load(plans_path)
    assert sorted(doc["learned"]) == [r0["plan_key"]]
    assert doc["learned"][r0["plan_key"]]["capacity_factor"] == r0["learned_factor"]


def test_learned_state_warms_a_fresh_planner_in_a_new_run(tmp_path):
    """Second run against the same plan file starts from the learned factor
    (the restart-warm-start property the persistence exists for)."""
    plans_path = os.path.join(str(tmp_path), "plans.json")
    args = {"plans_path": plans_path, "scope": "global", "n": 256, "seed": 0}
    first = harness.run_multihost(
        "bodies.py:sort_learn_body", 2, args=args
    ).require_success()
    second = harness.run_multihost(
        "bodies.py:sort_learn_body", 2, args=args
    ).require_success()
    # same traffic, so the already-learned factor holds steady
    assert second.result()["learned_factor"] == first.result()["learned_factor"]
    assert _load(plans_path)["learned"][first.result()["plan_key"]]["observations"] >= 2
