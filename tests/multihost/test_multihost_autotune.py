"""Distributed autotune: the rank-coordinated sweep on real process meshes.

The acceptance bar for the tentpole: a ``Planner.autotune`` sweep run inside
a live ``jax.distributed`` job must land every rank on the same winning plan
(bit-identical tables, broadcast from rank 0), must elect rank 0 as the only
writer of the shared plan cache, and — the fault-injection battery — must
either complete identically on all ranks or fail *contained*: a rank killed
or hung mid-sweep never leaves a corrupt or partially-written cache behind.
"""
import json
import os

import pytest

import harness

pytestmark = pytest.mark.multihost


def _strict_load(plans_path):
    """Load the shared cache the way tooling does: strict, fresh planner."""
    from repro.engine.planner import Planner

    return Planner().load(plans_path, strict=True)


def _seed_cache(plans_path):
    """Pre-seed the shared cache with one known cell so the fault tests can
    prove a failed sweep preserved prior contents, not just an empty file."""
    from repro.engine.planner import Planner, SortPlan

    p = Planner()
    p.plans["32|int32|seed/fp"] = SortPlan("shared", us_per_call=1.0)
    p.save(plans_path)
    return "32|int32|seed/fp"


def _run_autotune(plans_path, nprocs, *, reps=2, fault=None, timeout=None):
    args = {"plans_path": plans_path, "n": 256, "reps": reps}
    if fault is not None:
        args["fault"] = fault
    kw = {} if timeout is None else {"timeout": timeout}
    return harness.run_multihost("bodies.py:autotune_body", nprocs, args=args, **kw)


# ------------------------------------------------------------ bit identity ---
def test_two_process_sweep_bit_identical_across_ranks_and_cache(tmp_path):
    plans_path = os.path.join(str(tmp_path), "plans.json")
    run = _run_autotune(plans_path, 2).require_success()
    r0, r1 = run.results()
    # the whole plan table — winner, timings, every cell — is bit-identical
    assert r0["best"] == r1["best"]
    assert r0["plans"] == r1["plans"]
    assert r0["plan_key"] == r1["plan_key"]
    assert "/procs2x1" in r0["mesh_fp"], r0["mesh_fp"]
    # ... and identical to the cache rank 0 wrote
    fresh = _strict_load(plans_path)
    assert fresh.plans[r0["plan_key"]].to_dict() == r0["best"]
    assert {k: p.to_dict() for k, p in fresh.plans.items()} == r0["plans"]


def test_four_process_sweep_bit_identical_across_ranks_and_cache(tmp_path):
    plans_path = os.path.join(str(tmp_path), "plans.json")
    run = _run_autotune(plans_path, 4).require_success()
    results = run.results()
    assert all(r["best"] == results[0]["best"] for r in results)
    assert all(r["plans"] == results[0]["plans"] for r in results)
    assert "/procs4x1" in results[0]["mesh_fp"]
    fresh = _strict_load(plans_path)
    assert fresh.plans[results[0]["plan_key"]].to_dict() == results[0]["best"]


def test_rank0_is_the_single_writer(tmp_path):
    """The single-writer election: rank 0 persisted the winner, every other
    rank only read the file the post-save barrier guaranteed was on disk."""
    plans_path = os.path.join(str(tmp_path), "plans.json")
    run = _run_autotune(plans_path, 2, reps=1).require_success()
    assert [r["wrote"] for r in run.results()] == [True, False]
    with open(plans_path) as f:
        doc = json.load(f)
    assert doc["version"] == 3
    (key,) = doc["plans"]
    assert key.endswith("/procs2x1"), key


# ------------------------------------------------- fault-injection battery ---
def test_rank_killed_mid_sweep_leaves_cache_uncorrupted(tmp_path):
    """Rank 1 dies hard between two timed candidates; rank 0 wedges in the
    next barrier and is reaped by the coordinator.  The sweep never reached
    its save, so the shared cache must still hold exactly the pre-seeded
    cell — strictly loadable, no partial writes, no leftover tmp files."""
    plans_path = os.path.join(str(tmp_path), "plans.json")
    seed_key = _seed_cache(plans_path)
    run = _run_autotune(
        plans_path,
        2,
        fault={"rank": 1, "point": "candidate:1", "kind": "crash"},
        timeout=120,
    )
    assert not run.ok, run.describe()
    assert run.reports[1].returncode == 13, run.describe()
    fresh = _strict_load(plans_path)
    assert sorted(fresh.plans) == [seed_key]
    assert fresh.learned == {}
    tmps = [f for f in os.listdir(str(tmp_path)) if ".tmp." in f]
    assert not tmps, f"partial plan-cache writes left behind: {tmps}"


def test_rank_hung_during_timed_collective_fails_contained(tmp_path):
    """Rank 1 wedges mid-sweep, leaving rank 0 blocked inside the candidate
    barrier (a real collective).  The run must end — gloo's own timeout or
    the harness deadline, whichever lands first — without the pytest run
    hanging and without the cache changing."""
    plans_path = os.path.join(str(tmp_path), "plans.json")
    seed_key = _seed_cache(plans_path)
    run = _run_autotune(
        plans_path,
        2,
        fault={"rank": 1, "point": "candidate:1", "kind": "hang"},
        timeout=75,
    )
    assert not run.ok, run.describe()
    assert all(not r.ok for r in run.reports)
    fresh = _strict_load(plans_path)
    assert sorted(fresh.plans) == [seed_key]


def test_two_concurrent_autotuners_merge_to_a_union_table(tmp_path):
    """Two uncoordinated autotuning processes (``distributed=False``) race
    rank-distinct cells into one shared cache: the fcntl-locked
    merge-on-save must union the tables — both cells survive, under their
    multi-process topology fingerprint, strictly loadable."""
    plans_path = os.path.join(str(tmp_path), "plans.json")
    run = harness.run_multihost(
        "bodies.py:autotune_local_body",
        2,
        args={"plans_path": plans_path, "base_n": 64, "reps": 2},
    ).require_success()
    r0, r1 = run.results()
    assert r0["plan_key"] != r1["plan_key"], "ranks must tune distinct cells"
    # every uncoordinated autotuner wrote its own cell itself
    assert [r["wrote"] for r in run.results()] == [True, True]
    fresh = _strict_load(plans_path)
    assert sorted(fresh.plans) == sorted([r0["plan_key"], r1["plan_key"]])
    assert fresh.plans[r0["plan_key"]].to_dict() == r0["best"]
    assert fresh.plans[r1["plan_key"]].to_dict() == r1["best"]
