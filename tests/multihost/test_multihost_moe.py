"""Adaptive MoE dispatch with a plan cache shared across real processes.

Every rank runs the same skewed ``moe_apply_adaptive`` forward against one
plan-cache file — the exact concurrent-writer scenario ``Planner.save``'s
locked read-merge-write exists for.  The file must end up with the merged
learned state, not whichever rank happened to save last.
"""
import json
import os

import pytest

import harness

pytestmark = pytest.mark.multihost


def test_moe_adaptive_learns_into_shared_plan_file(tmp_path):
    plans_path = os.path.join(str(tmp_path), "plans.json")
    run = harness.run_multihost(
        "bodies.py:moe_adaptive_body", 2, args={"plans_path": plans_path}
    ).require_success()
    r0, r1 = run.results()
    # replicated forward: both ranks computed the same thing and learned the
    # same factor for the same (global-scope) cell
    assert r0["y_sha"] == r1["y_sha"]
    assert r0["counts"] == r1["counts"]
    assert r0["scoped_key"] == r1["scoped_key"] == r0["plan_key"]
    assert r0["learned_factor"] == r1["learned_factor"] > 1.0

    with open(plans_path) as f:
        doc = json.load(f)
    assert doc["version"] == 3
    # one merged entry — two concurrent writers, zero clobbering
    assert sorted(doc["learned"]) == [r0["plan_key"]]
    entry = doc["learned"][r0["plan_key"]]
    assert entry["capacity_factor"] == r0["learned_factor"]
    assert entry["observations"] >= 1


def test_moe_adaptive_bit_identical_to_single_process(tmp_path):
    multi = harness.run_multihost(
        "bodies.py:moe_adaptive_body",
        2,
        args={"plans_path": os.path.join(str(tmp_path), "a.json")},
    ).require_success()
    forced = harness.run_forced_mesh(
        "bodies.py:moe_adaptive_body",
        1,
        args={"plans_path": os.path.join(str(tmp_path), "b.json")},
    ).require_success()
    m, f = multi.result(), forced.result()
    assert m["y_sha"] == f["y_sha"], "MoE forward must not depend on process count"
    assert m["counts"] == f["counts"]
    assert m["learned_factor"] == f["learned_factor"]
    # ...but the learned cells live under different topology fingerprints
    assert m["plan_key"] != f["plan_key"]
    assert "/procs2x1" in m["plan_key"]


# ------------------------------------------------- expert-parallel training ---
def _check_trained_cell(plans_path, report):
    """The body's learned factor must be durable in the shared file."""
    with open(plans_path) as f:
        doc = json.load(f)
    assert doc["version"] == 3
    entry = doc["learned"][report["plan_key"]]
    assert entry["capacity_factor"] == report["learned_factor"]
    assert entry["observations"] >= 1


def test_moe_train_step_learns_on_two_process_mesh(tmp_path):
    """The between-step capacity loop on a 2-process x 2-device (data=2,
    model=2) mesh: every rank sees the same integer dropped/peak trace,
    step 0 pays the overflow, step 1 runs drop-free at the learned
    capacity, and the factor lands in the shared plan file under the
    2x2-process cell."""
    plans_path = os.path.join(str(tmp_path), "plans.json")
    run = harness.run_multihost(
        "bodies.py:moe_train_step_body", 2, local_devices=2,
        args={"plans_path": plans_path},
    ).require_success()
    r0, r1 = run.results()
    assert r0["trace"] == r1["trace"]
    assert r0["learned_factor"] == r1["learned_factor"] > 1.0
    assert r0["plan_key"] == r1["plan_key"]
    assert "/procs2x2" in r0["plan_key"]
    assert r0["trace"][0]["dropped"] > 0, "collapsed router must overflow step 0"
    assert all(t["dropped"] == 0 for t in r0["trace"][1:]), r0["trace"]
    assert r0["trace"][0]["cap"] < r0["trace"][1]["cap"]
    assert r0["losses_finite"] and r1["losses_finite"]
    _check_trained_cell(plans_path, r0)


def test_moe_train_step_bit_identical_across_topologies(tmp_path):
    """The same 4-device training job as 4 processes x 1 device and as the
    single-process forced mesh: the learned factor and the whole integer
    capacity trace must be bit-identical (only the plan cell's topology
    fingerprint differs) — the acceptance bar for trusting factors learned
    on one topology shape from another run of the same shape."""
    four_path = os.path.join(str(tmp_path), "four.json")
    ref_path = os.path.join(str(tmp_path), "ref.json")
    four = harness.run_multihost(
        "bodies.py:moe_train_step_body", 4, local_devices=1,
        args={"plans_path": four_path},
    ).require_success()
    ref = harness.run_forced_mesh(
        "bodies.py:moe_train_step_body", 4, args={"plans_path": ref_path}
    ).require_success()
    m, f = four.result(), ref.result()
    assert m["trace"] == f["trace"], "integer capacity trace must not depend on process count"
    assert m["learned_factor"] == f["learned_factor"]
    assert m["plan_key"] != f["plan_key"]
    assert "/procs4x1" in m["plan_key"]
    assert "procs" not in f["plan_key"]
    _check_trained_cell(four_path, m)
    _check_trained_cell(ref_path, f)
