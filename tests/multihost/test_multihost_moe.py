"""Adaptive MoE dispatch with a plan cache shared across real processes.

Every rank runs the same skewed ``moe_apply_adaptive`` forward against one
plan-cache file — the exact concurrent-writer scenario ``Planner.save``'s
locked read-merge-write exists for.  The file must end up with the merged
learned state, not whichever rank happened to save last.
"""
import json
import os

import pytest

import harness

pytestmark = pytest.mark.multihost


def test_moe_adaptive_learns_into_shared_plan_file(tmp_path):
    plans_path = os.path.join(str(tmp_path), "plans.json")
    run = harness.run_multihost(
        "bodies.py:moe_adaptive_body", 2, args={"plans_path": plans_path}
    ).require_success()
    r0, r1 = run.results()
    # replicated forward: both ranks computed the same thing and learned the
    # same factor for the same (global-scope) cell
    assert r0["y_sha"] == r1["y_sha"]
    assert r0["counts"] == r1["counts"]
    assert r0["scoped_key"] == r1["scoped_key"] == r0["plan_key"]
    assert r0["learned_factor"] == r1["learned_factor"] > 1.0

    with open(plans_path) as f:
        doc = json.load(f)
    assert doc["version"] == 2
    # one merged entry — two concurrent writers, zero clobbering
    assert sorted(doc["learned"]) == [r0["plan_key"]]
    entry = doc["learned"][r0["plan_key"]]
    assert entry["capacity_factor"] == r0["learned_factor"]
    assert entry["observations"] >= 1


def test_moe_adaptive_bit_identical_to_single_process(tmp_path):
    multi = harness.run_multihost(
        "bodies.py:moe_adaptive_body",
        2,
        args={"plans_path": os.path.join(str(tmp_path), "a.json")},
    ).require_success()
    forced = harness.run_forced_mesh(
        "bodies.py:moe_adaptive_body",
        1,
        args={"plans_path": os.path.join(str(tmp_path), "b.json")},
    ).require_success()
    m, f = multi.result(), forced.result()
    assert m["y_sha"] == f["y_sha"], "MoE forward must not depend on process count"
    assert m["counts"] == f["counts"]
    assert m["learned_factor"] == f["learned_factor"]
    # ...but the learned cells live under different topology fingerprints
    assert m["plan_key"] != f["plan_key"]
    assert "/procs2x1" in m["plan_key"]
