"""tests/multihost plumbing.

* The directory is not a package; put it on ``sys.path`` so the test
  modules can ``import harness``.
* Everything in here is marked ``multihost`` and **skipped unless the run
  opted in with ``-m multihost``** — each test launches several real
  ``jax.distributed`` processes, which the fast tier-1 suite must not pay
  for (and must not be able to destabilize).
"""
import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))


def pytest_collection_modifyitems(config, items):
    if "multihost" in (config.getoption("-m") or ""):
        return
    skip = pytest.mark.skip(reason="multihost harness tests run with -m multihost")
    for item in items:
        if "multihost" in item.keywords:
            item.add_marker(skip)
