"""The unified exchange layer over real cross-process all_to_all.

``partition_exchange`` / ``combine_exchange`` — including the int8
compressed wire — must produce byte-identical results whether the mesh
spans one process or several.  Bodies assert round-trip correctness
in-process; here we compare the content hashes across topologies.
"""
import pytest

import harness

pytestmark = pytest.mark.multihost


def test_exchange_roundtrip_2proc_bit_identical_to_forced():
    args = {"seed": 1, "m": 32, "d": 4}
    multi = harness.run_multihost(
        "bodies.py:exchange_roundtrip_body", 2, args=args
    ).require_success()
    forced = harness.run_forced_mesh(
        "bodies.py:exchange_roundtrip_body", 2, args=args
    ).require_success()
    r, f = multi.result(), forced.result()
    assert r == f, f"exchange hashes differ across topologies: {r} vs {f}"
    # and both ranks of the multi-process run saw the same bytes
    assert multi.result(0) == multi.result(1)


def test_exchange_roundtrip_4proc():
    args = {"seed": 2, "m": 16, "d": 8}
    multi = harness.run_multihost(
        "bodies.py:exchange_roundtrip_body", 4, args=args
    ).require_success()
    results = multi.results()
    assert all(r == results[0] for r in results)
    forced = harness.run_forced_mesh(
        "bodies.py:exchange_roundtrip_body", 4, args=args
    ).require_success()
    assert results[0] == forced.result()
