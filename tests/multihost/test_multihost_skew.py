"""Sample partition + skew auto-promotion across real multi-process meshes.

The single-process skew battery (tests/test_skew.py) proves the sample
partition balances adversarial key distributions; these tests prove the
same machinery across genuinely separate ``jax.distributed`` processes:

* sample-mode ``cluster_sort`` on 2- and 4-process meshes is bit-identical
  to the single-process forced-mesh reference (the composite-splitter
  all-gather must be a pure re-plumbing of the same math), and
* the radix->sample auto-promotion loop runs end to end with every rank
  learning into one shared, fcntl-locked plan-cache file — same per-step
  trajectory on every rank, same trajectory as the forced-mesh run, and a
  promoted partition that persists through the cache into a fresh planner.
"""
import json
import os

import pytest

import harness

pytestmark = pytest.mark.multihost


def test_sample_mode_2proc_bit_identical_to_forced():
    args = {"n": 256, "seed": 13, "mode": "sample"}
    multi = harness.run_multihost(
        "bodies.py:cluster_sort_body", 2, args=args
    ).require_success()
    forced = harness.run_forced_mesh(
        "bodies.py:cluster_sort_body", 2, args=args
    ).require_success()
    r0, r1 = multi.results()
    assert r0["sorted"] == r1["sorted"], "ranks disagree on sample-mode output"
    assert r0["sorted"] == forced.result()["sorted"], (
        "2-process sample-mode sort differs from the single-process "
        "2-device reference"
    )


def test_sample_mode_4proc_bit_identical_to_forced():
    args = {"n": 512, "seed": 21, "mode": "sample"}
    multi = harness.run_multihost(
        "bodies.py:cluster_sort_body", 4, args=args
    ).require_success()
    results = multi.results()
    assert all(r["sorted"] == results[0]["sorted"] for r in results)
    forced = harness.run_forced_mesh(
        "bodies.py:cluster_sort_body", 4, args=args
    ).require_success()
    assert results[0]["sorted"] == forced.result()["sorted"]


def _check_promotion_trace(trace):
    """The canonical trajectory: a radix era accruing strikes, a latch, then
    a balanced zero-retry sample era."""
    assert trace[0]["mode"] == "radix" and trace[0]["partition"] == "radix"
    assert trace[0]["promoted"] is None
    flip = next(i for i, t in enumerate(trace) if t["promoted"] == "sample")
    assert trace[flip]["strikes"] >= 3
    post = trace[flip + 1:]
    assert post, "need post-promotion steps in the trace"
    for t in post:
        assert t["mode"] == "sample" and t["partition"] == "sample"
        assert t["retries"] == 0, f"promoted cell still overflowing: {t}"
        assert t["ratio"] <= 1.5, f"promoted cell still skewed: {t}"
    return flip


def test_skew_promotion_2proc_persists_through_locked_cache(tmp_path):
    # a 2-shard mesh has 2 buckets, so peak/mean tops out at exactly 2.0 and
    # can never *exceed* the default promote_ratio — the body lowers the
    # threshold for this topology (see skew_promotion_body)
    plans_path = os.path.join(str(tmp_path), "plans.json")
    args = {
        "plans_path": plans_path, "n": 256, "seed": 2, "steps": 5,
        "promote_ratio": 1.5,
    }
    run = harness.run_multihost(
        "bodies.py:skew_promotion_body", 2, args=args
    ).require_success()
    r0, r1 = run.results()
    assert r0["trace"] == r1["trace"], "ranks disagree on the promotion path"
    _check_promotion_trace(r0["trace"])
    assert r0["restart_partition"] == "sample"
    assert r0["restart_mode"] == "sample", (
        "a restarted planner's serving path must inject sample mode"
    )

    # the shared file both ranks wrote through the fcntl lock carries the
    # latch in v3 schema
    with open(plans_path) as f:
        doc = json.load(f)
    assert doc["version"] == 3
    (entry,) = doc["learned"].values()
    assert entry["partition"] == "sample" and entry["skew_strikes"] >= 3

    # the forced-mesh reference walks the identical trajectory (own file:
    # its fingerprint is a different cell, but the math must match)
    forced = harness.run_forced_mesh(
        "bodies.py:skew_promotion_body", 2,
        args={**args, "plans_path": os.path.join(str(tmp_path), "forced.json")},
    ).require_success()
    assert forced.result()["trace"] == r0["trace"]
    assert forced.result()["sorted"] == r0["sorted"]


def test_skew_promotion_4proc_default_threshold(tmp_path):
    # 4 buckets: Zipf concentrates ~all keys into one, ratio ~4 > the
    # default promote_ratio, and cf=2.0 capacity genuinely overflows — the
    # full production configuration, no threshold override
    plans_path = os.path.join(str(tmp_path), "plans.json")
    args = {"plans_path": plans_path, "n": 512, "seed": 4, "steps": 5}
    run = harness.run_multihost(
        "bodies.py:skew_promotion_body", 4, args=args
    ).require_success()
    results = run.results()
    assert all(r["trace"] == results[0]["trace"] for r in results)
    flip = _check_promotion_trace(results[0]["trace"])
    assert results[0]["trace"][0]["retries"] >= 1, (
        "radix mode should pay overflow retries on 4-bucket Zipf data"
    )
    assert flip >= 2, "promotion needs persistent skew, not one bad call"
    assert all(r["restart_partition"] == "sample" for r in results)
    assert results[0]["restart_mode"] == "sample"
