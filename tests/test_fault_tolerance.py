"""Fault-tolerance control plane: watchdog, anomaly monitor, recovery loop."""
import time

import pytest

from repro.distributed.fault_tolerance import (
    AnomalyMonitor,
    StepTimeout,
    StepWatchdog,
    TrainingAnomaly,
    run_with_recovery,
)


def test_watchdog_passes_fast_step():
    with StepWatchdog(5.0):
        time.sleep(0.01)


def test_watchdog_raises_on_timeout():
    with pytest.raises(StepTimeout):
        with StepWatchdog(0.05):
            time.sleep(0.2)


def test_monitor_nan_loss():
    with pytest.raises(TrainingAnomaly):
        AnomalyMonitor().check({"loss": float("nan")})


def test_monitor_grad_explosion():
    with pytest.raises(TrainingAnomaly):
        AnomalyMonitor(grad_norm_limit=10).check({"loss": 1.0, "grad_norm": 100.0})


def test_monitor_overflow_patience():
    m = AnomalyMonitor(overflow_patience=3)
    m.check({"loss": 1.0, "moe_overflow": True})
    m.check({"loss": 1.0, "moe_overflow": True})
    with pytest.raises(TrainingAnomaly):
        m.check({"loss": 1.0, "moe_overflow": True})
    # streak resets on a clean step
    m2 = AnomalyMonitor(overflow_patience=2)
    m2.check({"loss": 1.0, "moe_overflow": True})
    m2.check({"loss": 1.0, "moe_overflow": False})
    m2.check({"loss": 1.0, "moe_overflow": True})  # no raise


def test_recovery_restores_and_replays():
    """A step that fails once recovers from the last checkpoint and finishes."""
    state = {"ckpt": 0, "failed": False}
    log = []

    def step(i):
        if i == 7 and not state["failed"]:
            state["failed"] = True
            return {"loss": float("nan")}
        log.append(i)
        return {"loss": 1.0}

    def save(i):
        state["ckpt"] = i

    def restore():
        return state["ckpt"]

    summary = run_with_recovery(
        n_steps=10, step_fn=step, save_fn=save, restore_fn=restore,
        checkpoint_every=5, max_restarts=2,
    )
    assert summary["steps_run"] == 10
    assert summary["restarts"] == 1
    assert 7 in log  # replayed after restore


def test_recovery_gives_up_after_max_restarts():
    def bad_step(i):
        return {"loss": float("nan")}

    with pytest.raises(TrainingAnomaly):
        run_with_recovery(
            n_steps=3, step_fn=bad_step, save_fn=lambda i: None,
            restore_fn=lambda: 0, max_restarts=2,
        )


# --- ExchangeObservation.dropped -> routing-collapse signal (PR 6) ---------

def _obs(dropped=0, averted=0):
    from repro.exchange.telemetry import ExchangeObservation
    return ExchangeObservation(m=64, part_buckets=4, capacity=16, peak=20,
                               overflowed=dropped > 0 or averted > 0,
                               retries=int(averted > 0), dropped=dropped,
                               dropped_averted=averted)


def test_watch_exchange_folds_served_drops_into_overflow_signal():
    from repro.exchange.telemetry import ExchangeTelemetry

    led = ExchangeTelemetry()
    mon = AnomalyMonitor(overflow_patience=3).watch_exchange(led)
    # clean steps don't advance the streak
    mon.check({"loss": 1.0})
    for i in range(2):
        led.record("moe/E4k1|64|float32|local", _obs(dropped=5))
        mon.check({"loss": 1.0})
    assert mon.dropped_total == 10
    led.record("moe/E4k1|64|float32|local", _obs(dropped=1))
    with pytest.raises(TrainingAnomaly, match="tokens dropped"):
        mon.check({"loss": 1.0})


def test_watch_exchange_ignores_averted_drops():
    from repro.exchange.telemetry import ExchangeTelemetry

    led = ExchangeTelemetry()
    mon = AnomalyMonitor(overflow_patience=1).watch_exchange(led)
    # the adaptive path retried loss-free: no served-output corruption,
    # so no anomaly no matter how many times it happens
    for _ in range(5):
        led.record("moe/E4k1|64|float32|local", _obs(averted=7))
        mon.check({"loss": 1.0})
    assert mon.dropped_total == 0


def test_watch_exchange_streak_resets_on_clean_step():
    from repro.exchange.telemetry import ExchangeTelemetry

    led = ExchangeTelemetry()
    mon = AnomalyMonitor(overflow_patience=2).watch_exchange(led)
    led.record("k", _obs(dropped=3))
    mon.check({"loss": 1.0})       # streak 1
    mon.check({"loss": 1.0})       # clean -> reset
    led.record("k", _obs(dropped=3))
    mon.check({"loss": 1.0})       # streak 1 again, no raise
    assert mon.dropped_total == 6


def test_telemetry_subscribers_see_every_record():
    from repro.exchange.telemetry import ExchangeTelemetry

    led = ExchangeTelemetry()
    seen = []
    led.subscribe(lambda key, obs: seen.append((key, obs.dropped)))
    led.record("a", _obs(dropped=2))
    led.record("b", _obs())
    assert seen == [("a", 2), ("b", 0)]
    # subscribers run outside the ledger lock: reading back must not deadlock
    led.subscribe(lambda key, obs: led.last(key))
    led.record("a", _obs(dropped=1))
    assert led.total_dropped == 3


def test_telemetry_and_monitor_survive_concurrent_observers():
    """Observations arrive from whichever thread ran the dispatch (sync
    callers, the async queue, concurrent warmups).  Subscriber delivery and
    the monitor's drop counters must not lose updates under that load."""
    import threading

    from repro.exchange.telemetry import ExchangeTelemetry

    led = ExchangeTelemetry()
    mon = AnomalyMonitor(overflow_patience=10**9).watch_exchange(led)
    seen = []
    seen_lock = threading.Lock()

    def subscriber(key, obs):
        with seen_lock:
            seen.append((key, obs.dropped))

    led.subscribe(subscriber)

    n_threads, per_thread = 8, 50
    start = threading.Barrier(n_threads)

    def work(t):
        start.wait()  # maximize interleaving
        for i in range(per_thread):
            led.record(f"k{t}", _obs(dropped=1 if i % 2 == 0 else 0))

    threads = [threading.Thread(target=work, args=(t,)) for t in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()

    total = n_threads * per_thread
    drops = n_threads * (per_thread // 2)
    assert len(seen) == total, "subscriber missed records"
    assert sum(d for _, d in seen) == drops
    assert mon.dropped_total == drops, "monitor lost concurrent drop updates"
    assert led.total_dropped == drops
    assert led.calls == total
    for t in range(n_threads):
        assert led.last(f"k{t}") is not None
    # one check() drains the whole pending backlog exactly once
    mon.check({"loss": 1.0})
    mon.check({"loss": 1.0})
    assert mon.dropped_total == drops


def test_subscribers_added_mid_stream_see_only_later_records():
    from repro.exchange.telemetry import ExchangeTelemetry

    led = ExchangeTelemetry()
    led.record("a", _obs(dropped=1))
    late = []
    led.subscribe(lambda key, obs: late.append(key))
    led.record("b", _obs())
    assert late == ["b"]
