"""Fault-tolerance control plane: watchdog, anomaly monitor, recovery loop."""
import time

import pytest

from repro.distributed.fault_tolerance import (
    AnomalyMonitor,
    StepTimeout,
    StepWatchdog,
    TrainingAnomaly,
    run_with_recovery,
)


def test_watchdog_passes_fast_step():
    with StepWatchdog(5.0):
        time.sleep(0.01)


def test_watchdog_raises_on_timeout():
    with pytest.raises(StepTimeout):
        with StepWatchdog(0.05):
            time.sleep(0.2)


def test_monitor_nan_loss():
    with pytest.raises(TrainingAnomaly):
        AnomalyMonitor().check({"loss": float("nan")})


def test_monitor_grad_explosion():
    with pytest.raises(TrainingAnomaly):
        AnomalyMonitor(grad_norm_limit=10).check({"loss": 1.0, "grad_norm": 100.0})


def test_monitor_overflow_patience():
    m = AnomalyMonitor(overflow_patience=3)
    m.check({"loss": 1.0, "moe_overflow": True})
    m.check({"loss": 1.0, "moe_overflow": True})
    with pytest.raises(TrainingAnomaly):
        m.check({"loss": 1.0, "moe_overflow": True})
    # streak resets on a clean step
    m2 = AnomalyMonitor(overflow_patience=2)
    m2.check({"loss": 1.0, "moe_overflow": True})
    m2.check({"loss": 1.0, "moe_overflow": False})
    m2.check({"loss": 1.0, "moe_overflow": True})  # no raise


def test_recovery_restores_and_replays():
    """A step that fails once recovers from the last checkpoint and finishes."""
    state = {"ckpt": 0, "failed": False}
    log = []

    def step(i):
        if i == 7 and not state["failed"]:
            state["failed"] = True
            return {"loss": float("nan")}
        log.append(i)
        return {"loss": 1.0}

    def save(i):
        state["ckpt"] = i

    def restore():
        return state["ckpt"]

    summary = run_with_recovery(
        n_steps=10, step_fn=step, save_fn=save, restore_fn=restore,
        checkpoint_every=5, max_restarts=2,
    )
    assert summary["steps_run"] == 10
    assert summary["restarts"] == 1
    assert 7 in log  # replayed after restore


def test_recovery_gives_up_after_max_restarts():
    def bad_step(i):
        return {"loss": float("nan")}

    with pytest.raises(TrainingAnomaly):
        run_with_recovery(
            n_steps=3, step_fn=bad_step, save_fn=lambda i: None,
            restore_fn=lambda: 0, max_restarts=2,
        )
