"""Training-side MoE capacity loop: empty/single-token dispatch edges, the
train_step stats plumbing, the between-step learning loop (a skewed router
pays its overflow at most once, with zero fresh lowerings after the one
bump), and the train -> serve warm start through the shared plan cache."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import REPO, run_with_devices
from repro.models.moe import (
    MoEConfig,
    moe_apply_adaptive,
    moe_apply_ep_replicated,
    moe_apply_local_adaptive,
    moe_init,
)

# ------------------------------------------------- T=0 / T=1 edge cases ---


@pytest.mark.parametrize("T", [0, 1])
def test_replicated_path_handles_tiny_batches(key, T):
    """T=0 (drained microbatch) and T=1 must produce finite outputs and a
    finite aux loss — the router's load-balance term divides by T."""
    cfg = MoEConfig(d_model=8, d_ff=4, n_experts=4, top_k=2, capacity_factor=2.0)
    p = moe_init(key, cfg, jnp.float32, ep_shards=1)
    x = jax.random.normal(jax.random.PRNGKey(1), (T, 8))
    y, aux, dropped, counts, peak, overflow = moe_apply_ep_replicated(
        p, cfg, x, with_stats=True
    )
    assert y.shape == (T, 8)
    assert np.isfinite(np.asarray(y)).all() and np.isfinite(float(aux))
    assert int(dropped) == 0 and not bool(overflow)
    assert int(counts.sum()) == T * cfg.top_k
    assert int(peak) <= max(T, 1)


@pytest.mark.parametrize("T", [0, 1])
def test_adaptive_paths_handle_tiny_batches(key, T):
    """Both adaptive entry points (replicated and 1-device mesh) survive
    empty and single-token batches: expert_capacity floors at 1, so the
    compiled forwards always see well-formed >=1-slot slabs."""
    cfg = MoEConfig(d_model=8, d_ff=4, n_experts=4, top_k=2, capacity_factor=2.0)
    p = moe_init(key, cfg, jnp.float32, ep_shards=1)
    x = jax.random.normal(jax.random.PRNGKey(2), (T, 8))

    y, aux, counts = moe_apply_adaptive(p, cfg, x, capacity_factor=2.0)
    assert y.shape == (T, 8) and np.isfinite(np.asarray(y)).all()
    assert int(counts.sum()) == T * cfg.top_k

    mesh = jax.make_mesh((1,), ("x",))
    y2, aux2, counts2 = moe_apply_local_adaptive(
        p, cfg, x, mesh, axes=("x",), ep_axis="x", capacity_factor=2.0
    )
    assert y2.shape == (T, 8) and np.isfinite(np.asarray(y2)).all()
    assert np.isfinite(float(aux2))
    assert int(counts2.sum()) == T * cfg.top_k
    if T:  # identical routing on 1 device -> identical outputs
        np.testing.assert_allclose(np.asarray(y2), np.asarray(y), atol=1e-5)


# ------------------------------------- train_step stats + capacity loop ---

_TINY_MOE_ARCH = """
    from dataclasses import replace
    import jax.numpy as jnp
    from repro.configs.base import ARCHS
    cfg = replace(
        ARCHS["qwen3-0.6b"], name="t",
        n_layers=1, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
        d_ff=32, vocab_size=64, kv_chunk=16,
        pattern=("attn",), ffn_pattern=("moe",),
        n_experts=8, top_k=2, capacity_factor=1.0,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )
"""


def test_train_step_surfaces_drop_and_peak_stats():
    """loss_fn/train_step thread moe_dropped/moe_peak out of the jitted
    stack on a forced expert-parallel mesh: a collapsed router at a starved
    capacity reports drops and a peak above capacity; a generous capacity
    reports zero drops.  This is the signal the between-step controller
    feeds on — if it silently vanishes, capacity learning dies."""
    run_with_devices(_TINY_MOE_ARCH + """
    import functools
    import jax, jax.numpy as jnp, numpy as np
    from repro.models.moe import collapse_router
    from repro.models.transformer import ShardCtx, model_init
    from repro.optim.adamw import OptConfig, init_opt_state
    from repro.train.adaptive import parse_mesh_spec
    from repro.train.steps import loss_fn, train_step

    mesh, axes = parse_mesh_spec("data=2,model=4")
    ctx = ShardCtx(mesh=mesh, axes=axes)
    params = model_init(jax.random.PRNGKey(0), cfg, ep_shards=ctx.ep_shards)
    params["blocks"] = {
        pos: ({**gp, "moe": collapse_router(gp["moe"], 6.0)} if "moe" in gp else gp)
        for pos, gp in params["blocks"].items()
    }
    rng = np.random.default_rng(0)
    tok = rng.integers(1, cfg.vocab_size, (4, 33)).astype(np.int32)
    batch = {"tokens": jnp.asarray(tok[:, :-1]), "labels": jnp.asarray(tok[:, 1:])}

    # loss_fn alone surfaces the stats (the controller's signal source)
    loss, stats = loss_fn(params, cfg, batch, ctx=ctx, loss_chunk=32, moe_capacity=2)
    assert {"moe_dropped", "moe_peak"} <= set(stats), sorted(stats)
    assert int(stats["moe_dropped"]) > 0
    assert int(stats["moe_peak"]) > 2

    ocfg = OptConfig(peak_lr=1e-4, warmup_steps=2, total_steps=4)
    opt = init_opt_state(params, ocfg)
    step = functools.partial(train_step, cfg=cfg, opt_cfg=ocfg, ctx=ctx,
                             n_microbatch=1, loss_chunk=32)
    _, _, m_starved = jax.jit(functools.partial(step, moe_capacity=2))(params, opt, batch)
    assert int(m_starved["moe_dropped"]) > 0
    assert int(m_starved["moe_peak"]) > 2
    assert np.isfinite(float(m_starved["loss"]))

    # generous capacity: every assignment lands, peak is the true demand
    _, _, m_full = jax.jit(functools.partial(step, moe_capacity=31))(params, opt, batch)
    assert int(m_full["moe_dropped"]) == 0
    assert int(m_full["moe_peak"]) == int(m_starved["moe_peak"])
    print("ok")
    """)


def test_capacity_loop_pays_overflow_once_and_persists(tmp_path):
    """The acceptance loop: a skewed-router MoE LM trained through the
    MoECapacityController overflows on step 0, recompiles once at the
    learned capacity, then runs drop-free with ZERO fresh jit lowerings —
    and the learned factor lands in the plan cache under the mesh cell."""
    plans = str(tmp_path / "plans.json")
    run_with_devices(_TINY_MOE_ARCH + f"""
    import functools
    import jax, jax.numpy as jnp, numpy as np
    from jax._src import test_util as jtu
    from repro.engine.planner import Planner
    from repro.models.moe import collapse_router
    from repro.models.transformer import ShardCtx, model_init
    from repro.optim.adamw import OptConfig, init_opt_state
    from repro.train.adaptive import MoECapacityController, parse_mesh_spec
    from repro.train.steps import train_step

    mesh, axes = parse_mesh_spec("data=2,model=4")
    ctx = ShardCtx(mesh=mesh, axes=axes)
    params = model_init(jax.random.PRNGKey(0), cfg, ep_shards=ctx.ep_shards)
    params["blocks"] = {{
        pos: ({{**gp, "moe": collapse_router(gp["moe"], 6.0)}} if "moe" in gp else gp)
        for pos, gp in params["blocks"].items()
    }}
    ocfg = OptConfig(peak_lr=1e-4, warmup_steps=2, total_steps=4)
    opt = init_opt_state(params, ocfg)
    planner = Planner({plans!r})
    ctl = MoECapacityController(cfg.moe_cfg(), tokens=4 * 32, ctx=ctx,
                                planner=planner, dtype=cfg.compute_dtype)

    @functools.lru_cache(maxsize=None)
    def step_fn(cap):
        return jax.jit(functools.partial(
            train_step, cfg=cfg, opt_cfg=ocfg, ctx=ctx,
            n_microbatch=1, loss_chunk=32, moe_capacity=cap))

    rng = np.random.default_rng(0)

    def one_step():
        tok = rng.integers(1, cfg.vocab_size, (4, 33)).astype(np.int32)
        batch = {{"tokens": jnp.asarray(tok[:, :-1]),
                  "labels": jnp.asarray(tok[:, 1:])}}
        cap = ctl.capacity
        params2, opt2, m = step_fn(cap)(params, opt, batch)
        m = {{k: float(v) if jnp.ndim(v) == 0 else v for k, v in m.items()}}
        ctl.observe(m, capacity=cap)
        return cap, int(m["moe_dropped"]), float(m["loss"])

    caps, drops, losses = [], [], []
    for _ in range(2):
        c, d, l = one_step()
        caps.append(c); drops.append(d); losses.append(l)

    # steps 2..3 run at the learned capacity: no drops, no fresh lowerings
    with jtu.count_jit_and_pmap_lowerings() as count:
        for _ in range(2):
            c, d, l = one_step()
            caps.append(c); drops.append(d); losses.append(l)
    assert count[0] == 0, f"steady-state train step re-traced: {{count[0]}}"

    assert drops[0] > 0, "collapsed router at cf=1.0 must overflow step 0"
    assert drops[1:] == [0, 0, 0], f"overflow paid more than once: {{drops}}"
    assert caps[0] < caps[1] and len(set(caps[1:])) == 1, caps
    assert all(np.isfinite(l) for l in losses), losses
    assert "/data=2,model=4" in ctl.key, ctl.key
    planner.save()
    print("cell", ctl.key, "cf", ctl.factor)
    """)
    # the factor is durable: a fresh planner (fresh process would do the
    # same) reads it back above the config default
    from repro.engine.planner import Planner

    doc = json.load(open(plans))
    assert doc["version"] == 3
    cells = [k for k in doc["learned"] if k.startswith("moe/")]
    assert len(cells) == 1 and "data=2,model=4" in cells[0], cells
    assert Planner(plans).capacity_factor_for(cells[0], default=1.0) > 1.0


def test_capacity_bucketing_pins_lowerings_under_decay():
    """A calm era geometrically decays the learned factor toward the config
    default; since the driver keys compiled step functions on the static
    capacity, an *unbucketed* capacity would drift by a few tokens step
    after step and pay a fresh lowering almost every time.  The pow2 bucket
    must compress a whole decay trace into a handful of lowerings — this
    deterministic trace pins the count."""
    from types import SimpleNamespace

    from repro.exchange import expert_capacity
    from repro.train.adaptive import MoECapacityController

    # the factor trace a CapacityLearner produces after skew ends: geometric
    # decay from the skew-era high-water mark back to the default
    factors = [max(1.0, 4.0 * (0.93 ** i)) for i in range(40)]

    class DecayPlanner:
        def __init__(self):
            self.i = 0

        def capacity_factor_for(self, key, default=1.0):
            return factors[min(self.i, len(factors) - 1)]

    cfg = MoEConfig(d_model=8, d_ff=4, n_experts=8, top_k=2, capacity_factor=1.0)
    ctl = MoECapacityController(
        cfg, tokens=128, ctx=SimpleNamespace(mesh=None, axes=()),
        planner=DecayPlanner(),
    )

    caps, lowered = [], set()
    for i in range(len(factors)):
        ctl.planner.i = i
        cap = ctl.capacity
        caps.append(cap)
        lowered.add(cap)  # the lru-keyed step table compiles once per value

    raw = [
        expert_capacity(ctl.t_loc, cfg.top_k, cfg.n_experts, f) for f in factors
    ]
    assert len(set(raw)) > 10, "the decay must actually move the raw capacity"
    assert len(lowered) <= 4, f"bucketed decay must stay cheap: {sorted(lowered)}"
    # the bucket only ever rounds *up* (and m is the loss-free ceiling), so
    # bucketing never makes a step lossier than the raw capacity would be
    assert all(c >= r or c >= ctl.m for c, r in zip(caps, raw))
    assert all(c <= ctl.m for c in caps)
    assert caps == sorted(caps, reverse=True), "decay trace must be monotone"


def test_train_learned_factor_warm_starts_serving(tmp_path):
    """Cross-half acceptance: train a tiny skewed MoE LM (mesh=None cell),
    then start serve.py --moe against the same plan file and the same
    (E, k, token-bucket) cell — serving must warm-start at the trained
    factor with zero retries and zero dropped tokens."""
    plans = str(tmp_path / "plans.json")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["REPRO_SORT_PLANS"] = plans
    env.pop("XLA_FLAGS", None)  # single device -> mesh=None -> local/cpu cell

    # train: 1 step, so the router is still fully collapsed when the factor
    # persists — serving's identically-skewed router needs the same peak
    # (more steps rebalance the router and the factor legitimately decays)
    train = subprocess.run(
        [sys.executable, os.path.join(REPO, "examples", "train_lm.py"),
         "--moe", "--steps", "1"],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert train.returncode == 0, train.stderr
    assert "moe-train-smoke" in train.stdout, train.stdout
    doc = json.load(open(plans))
    trained = [k for k in doc["learned"] if k.startswith("moe/")]
    assert trained, doc["learned"].keys()

    # serve: same E=8/k=2, same T=4*32=128 token bucket, same local mesh
    serve = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--moe",
         "--moe-skew", "6.0", "--batch", "4", "--prompt-len", "32",
         "--gen", "2", "--experts", "8", "--stats"],
        env=env, capture_output=True, text=True, timeout=600, cwd=REPO,
    )
    assert serve.returncode == 0, serve.stderr
    assert "(retries=0)" in serve.stdout, serve.stdout
    assert "dropped=0 " in serve.stdout, serve.stdout
    assert "overflows=0" in serve.stdout, serve.stdout
