"""Shared test plumbing: multi-device subprocess runner + common fixtures.

Multi-device tests run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` because device count
is fixed at first jax import — the main pytest process stays at 1 device
(the dry-run isolation rule).  Import ``run_with_devices`` from here instead
of redefining it per file.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n: int = 8) -> str:
    """Run ``code`` in a fresh interpreter with ``n`` forced host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=env,
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


@pytest.fixture
def rng():
    """Deterministic numpy Generator, fresh per test."""
    return np.random.default_rng(0)


@pytest.fixture
def key():
    """Deterministic jax PRNG key, fresh per test."""
    import jax

    return jax.random.PRNGKey(0)


@pytest.fixture
def debug_mesh():
    """1-device mesh over whatever the main process exposes (api-level tests)."""
    import jax

    return jax.make_mesh((1,), ("x",))
