"""Checkpoint manager: roundtrip, atomicity, async, gc, pipeline state."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import SyntheticLM


def _tree():
    k = jax.random.PRNGKey(0)
    return {
        "a": jax.random.normal(k, (16, 8)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32), "c": jnp.zeros((3,), jnp.bfloat16)},
        "count": jnp.asarray(7, jnp.int32),
    }


def test_roundtrip_blocking(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree()
    mgr.save(3, t)
    restored, step = mgr.restore(t)
    assert step == 3
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_async_save_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree()
    mgr.save(1, t, blocking=False)
    mgr.save(2, t, blocking=False)  # waits for the first automatically
    mgr.wait()
    assert mgr.latest_step() == 2


def test_gc_keeps_last_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, t)
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
    assert steps == [3, 4]


def test_restore_missing_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        mgr.restore(_tree())


def test_restore_mesh_agnostic_resharding(tmp_path):
    """Leaves can be restored onto explicit shardings (elastic path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mgr = CheckpointManager(str(tmp_path))
    t = _tree()
    mgr.save(5, t)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
    restored, _ = mgr.restore(t, shardings=sh)
    assert restored["a"].sharding == NamedSharding(mesh, P())


def test_pipeline_state_resume_bit_exact(tmp_path):
    pipe = SyntheticLM(vocab=101, batch=2, seq=8, seed=3)
    it = iter(pipe)
    for _ in range(4):
        next(it)
    saved = pipe.checkpoint_state()
    want = next(iter(pipe))  # batch at step 4 (iterator advances state)

    pipe2 = SyntheticLM(vocab=101, batch=2, seq=8, seed=0)
    pipe2.restore_state(saved)
    got = next(iter(pipe2))
    np.testing.assert_array_equal(want["tokens"], got["tokens"])
    np.testing.assert_array_equal(want["labels"], got["labels"])
