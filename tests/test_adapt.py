"""Closed-loop adaptive tuning: capacity learning from exchange telemetry.

Property-based invariants for ``slab_geometry`` and the ``CapacityLearner``
(hypothesis when installed, the seeded shim otherwise — both deterministic),
the plan-cache v2 round-trip of learned state, and the acceptance regression:
a skewed range-mode workload that overflows at ``capacity_factor=2.0`` pays
exactly one retry on the first call and — after the telemetry round-trip —
zero retries and zero recompiles at the same plan-cache key.
"""
import os

import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare container — requirements-dev.txt installs the real one
    from _hypothesis_shim import given, settings, strategies as st

from conftest import run_with_devices
from repro.core.cluster_sort import slab_geometry
from repro.engine import (
    CapacityLearner,
    ExchangeObservation,
    ExchangeTelemetry,
    LearnedCapacity,
    Planner,
)
from repro.engine.planner import plan_key

settings.register_profile("repro-ci", max_examples=10, deadline=None,
                          derandomize=True)
settings.load_profile("repro-ci")

modes = st.sampled_from(("decimal", "splitters", "range"))
ms = st.integers(1, 1 << 14)
Ps = st.integers(1, 64)
cfs = st.floats(0.05, 64.0)
seeds = st.integers(0, 2**20)

DEFAULT_CF = 2.0


# ----------------------------------------------------- slab_geometry (D) ---
@given(modes, ms, Ps, cfs)
def test_slab_geometry_invariants(mode, m, P, cf):
    """For arbitrary (mode, m, P, capacity_factor): capacity stays within
    [1, m], the bucket grid is a multiple of P that covers every partitioner
    bucket, and a factor >= 1 provisions at least m slots across buckets."""
    part, n_buckets, cap = slab_geometry(mode, m, P, cf)
    assert part == (10 if mode == "decimal" else P)
    assert 1 <= cap <= m
    assert n_buckets % P == 0, "partition_exchange's B % P == 0 contract"
    assert n_buckets >= part, "slabs must cover all partitioner buckets"
    assert n_buckets - part < P, "bucket grid rounds up minimally"
    if cf >= 1.0:
        # enough total slots for every key on a uniform sender
        assert cap * part >= m
    # capacity is monotone in the factor (a bigger margin never shrinks slabs)
    _, _, cap2 = slab_geometry(mode, m, P, cf * 2)
    assert cap2 >= cap


# ----------------------------------------------------- capacity learner ----
def _random_observation(rng) -> ExchangeObservation:
    m = int(rng.integers(1, 1 << 12))
    part_buckets = int(rng.choice((8, 10, 16)))
    peak = int(rng.integers(0, m + 1))
    retries = int(rng.integers(0, 4))
    return ExchangeObservation(
        m=m,
        part_buckets=part_buckets,
        capacity=max(1, peak),
        peak=peak,
        overflowed=retries > 0,
        retries=retries,
        recompiles=int(rng.integers(0, retries + 1)),
    )


@given(st.integers(1, 60), seeds)
def test_capacity_learner_bounded_and_never_oscillates_past_peak(n_obs, seed):
    """For ANY observation sequence the learned factor stays within
    [default, max_factor] and never exceeds the largest observed
    peak-x-margin target — i.e. learning cannot run away or oscillate past
    what the telemetry justified."""
    rng = np.random.default_rng(seed)
    learner = CapacityLearner()
    learned = DEFAULT_CF
    max_target = DEFAULT_CF
    for _ in range(n_obs):
        obs = _random_observation(rng)
        target = learner.target(obs, default=DEFAULT_CF)
        max_target = max(max_target, target)
        prev = learned
        learned = learner.update(learned, obs, default=DEFAULT_CF)
        assert DEFAULT_CF <= learned <= learner.max_factor
        assert learned <= max_target + 1e-12, "overshot observed peak x margin"
        if target >= prev:
            assert learned == target, "pressure must be adopted immediately"
        else:
            assert learned <= prev, "calm traffic must never grow the factor"
            assert learned >= target, "decay must not undershoot the target"


@given(st.integers(1, 30), seeds)
def test_capacity_learner_decays_toward_default_when_calm(n_calm, seed):
    """After a burst of skew, a stream of calm observations walks the factor
    geometrically back toward the default (but never below it)."""
    learner = CapacityLearner()
    hot = ExchangeObservation(m=256, part_buckets=8, capacity=64, peak=256,
                              overflowed=True, retries=2)
    learned = learner.update(DEFAULT_CF, hot, default=DEFAULT_CF)
    assert learned == learner.target(hot, default=DEFAULT_CF) > DEFAULT_CF
    calm = ExchangeObservation(m=256, part_buckets=8, capacity=64, peak=0,
                               overflowed=False, retries=0)
    prev = learned
    for _ in range(n_calm):
        learned = learner.update(learned, calm, default=DEFAULT_CF)
        assert DEFAULT_CF <= learned <= prev
        prev = learned
    # decay is geometric: 30 calm steps from <= 64 land within a hair of 2.0
    if n_calm >= 30:
        assert learned == pytest.approx(DEFAULT_CF, rel=1e-6)


@given(st.integers(1, 20), seeds)
def test_learned_factors_roundtrip_through_plan_cache_json(n_obs, seed):
    """Any telemetry-fed learned table survives save -> load exactly (the
    plan-cache v2 'learned' section).  (tempfile, not the tmp_path fixture:
    function-scoped fixtures don't mix with @given.)"""
    import tempfile

    rng = np.random.default_rng(seed)
    path = os.path.join(tempfile.mkdtemp(), "plans.json")
    planner = Planner(path)
    keys = [plan_key(1 << k, jnp.int32) for k in (10, 12, 14)]
    for _ in range(n_obs):
        planner.observe_exchange(
            keys[int(rng.integers(0, len(keys)))], _random_observation(rng)
        )
    planner.save()
    reloaded = Planner(path)
    assert reloaded.learned == planner.learned
    for k in keys:
        assert reloaded.capacity_factor_for(k) == planner.capacity_factor_for(k)


# --------------------------------------------------- ledger + persistence ---
def test_exchange_telemetry_ledger_counts_and_windows():
    led = ExchangeTelemetry(window=4)
    key = plan_key(1024, jnp.int32)
    assert led.last(key) is None and led.peak_factor(key) == 0.0
    for peak in (10, 20, 120, 5, 8):
        led.record(key, ExchangeObservation(
            m=128, part_buckets=8, capacity=32, peak=peak,
            overflowed=peak > 32, retries=int(peak > 32)))
    assert led.calls == 5 and led.overflow_events == 1 and led.total_retries == 1
    assert led.last(key).peak == 8
    # the window dropped the first observation; peak_factor sees the rest
    assert led.peak_factor(key) == pytest.approx(120 * 8 / 128)
    assert led.keys() == [key]


def test_planner_v1_files_still_load_and_v2_learned_is_graceful(tmp_path):
    """Schema bump reuses the graceful-load path: v1 files (no 'learned')
    load cleanly, malformed learned sections warn + keep prior state, and
    unknown versions still warn."""
    import json
    import warnings

    v1 = tmp_path / "v1.json"
    v1.write_text(json.dumps({
        "version": 1,
        "plans": {plan_key(4096, jnp.int32): {
            "strategy": "shared", "local_impl": "xla"}},
    }))
    p = Planner(str(v1))
    assert p.lookup(4096, jnp.int32).local_impl == "xla"
    assert p.learned == {}

    # a v2 file with a rotted learned section is a rotted file: warn, keep
    bad = tmp_path / "bad_learned.json"
    bad.write_text(json.dumps({
        "version": 2, "plans": {},
        "learned": {"k": {"not_capacity": 1}},
    }))
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        p.load(str(bad))
    assert any("plan cache" in str(x.message) for x in w)
    assert p.lookup(4096, jnp.int32) is not None, "prior table survives"

    with pytest.raises(Exception):
        Planner().load(str(bad), strict=True)

    # a good v2 file round-trips both sections
    key = plan_key(8192, jnp.int32)
    p.learned[key] = LearnedCapacity(3.5, 2.8, 4)
    p.save(str(tmp_path / "v2.json"))
    p2 = Planner(str(tmp_path / "v2.json"))
    assert p2.learned[key].capacity_factor == 3.5
    assert p2.lookup(4096, jnp.int32).local_impl == "xla"


def test_plan_for_folds_learned_capacity_into_cluster_plans():
    planner = Planner()
    key = plan_key(1024, jnp.int32, None)
    # single-host default is a shared plan: learning must not touch it
    planner.learned[key] = LearnedCapacity(5.0, 4.0, 1)
    assert planner.plan_for(1024, jnp.int32).strategy == "shared"
    # a cluster plan for the same cell picks the learned factor up
    from repro.engine import SortPlan

    planner.plans[key] = SortPlan("cluster", capacity_factor=2.0)
    assert planner.plan_for(1024, jnp.int32).capacity_factor == 5.0


def test_service_stats_sink_sees_overflow_retries_and_recompiles():
    """The silent-telemetry-gap fix: exchange retries/recompiles observed by
    a service's planner land in ServiceStats instead of vanishing."""
    from repro.engine import SortService

    planner = Planner()
    svc = SortService(planner=planner)
    assert svc.stats.overflow_retries == 0 and svc.stats.recompiles == 0
    rec = planner.recorder(4096, jnp.int32)
    rec(m=512, part_buckets=8, capacity=128, peak=300, overflowed=True,
        retries=2, recompiles=2)
    rec(m=512, part_buckets=8, capacity=512, peak=300, overflowed=False,
        retries=0, recompiles=1)
    assert svc.stats.overflow_retries == 2
    assert svc.stats.recompiles == 3
    # the ledger kept the raw observations too
    assert planner.telemetry.total_retries == 2
    assert planner.telemetry.overflow_events == 1


# ----------------------------------------------- acceptance regression ------
def test_skewed_overflow_learns_capacity_and_stops_recompiling():
    """ISSUE acceptance: a duplicate-heavy range-mode workload overflowing at
    capacity_factor=2.0 pays exactly one retry on the first call; after the
    telemetry round-trip the same plan-cache key serves with zero retries and
    zero recompiles (asserted via jax's lowering counters) — and the learned
    factor survives a planner save/load (simulated process restart)."""
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp, tempfile, os
        from jax._src import test_util as jtu
        from repro.core.cluster_sort import cluster_sort, slab_geometry
        from repro.engine import Planner, cluster_sort_kv
        from repro.engine.planner import plan_key

        mesh = jax.make_mesh((8,), ("x",))
        n, P = 1024, 8
        m = n // P
        rng = np.random.default_rng(0)
        # keys concentrate in the low 3 of 8 range buckets over [0, 8000):
        # per-(sender, bucket) peak ~ m/3, above cap(2.0) but below one
        # doubling -> exactly one retry at the default factor
        x = rng.integers(0, 3000, n).astype(np.int32)
        kw = dict(mode="range", lo=0, hi=8000)
        _, _, cap0 = slab_geometry("range", m, P, 2.0)
        assert cap0 < m

        path = os.path.join(tempfile.mkdtemp(), "plans.json")
        planner = Planner(path)
        key = plan_key(n, jnp.int32, mesh)
        rec = planner.recorder(n, jnp.int32, mesh)

        # call 1: default factor overflows once, retries, learns
        slab, valid = cluster_sort(
            jnp.asarray(x), mesh, "x",
            capacity_factor=planner.capacity_factor_for(key),
            telemetry=rec, **kw)
        assert (np.asarray(slab)[np.asarray(valid)] == np.sort(x)).all()
        obs1 = planner.telemetry.last(key)
        assert obs1.overflowed and obs1.retries == 1, obs1
        assert obs1.recompiles >= 1
        cf = planner.capacity_factor_for(key)
        assert cf > 2.0 and cf >= obs1.required_factor()

        # call 2: learned factor -> zero retries (first compile at that cap)
        slab, valid = cluster_sort(jnp.asarray(x), mesh, "x",
                                   capacity_factor=cf, telemetry=rec, **kw)
        assert (np.asarray(slab)[np.asarray(valid)] == np.sort(x)).all()
        obs2 = planner.telemetry.last(key)
        assert not obs2.overflowed and obs2.retries == 0, obs2

        # steady state: same key, zero retries AND zero recompiles
        cf3 = planner.capacity_factor_for(key)
        with jtu.count_jit_and_pmap_lowerings() as count:
            slab, valid = cluster_sort(jnp.asarray(x), mesh, "x",
                                       capacity_factor=cf3, telemetry=rec, **kw)
        assert count[0] == 0, "steady-state cluster path must not re-trace"
        assert planner.telemetry.last(key).retries == 0
        assert (np.asarray(slab)[np.asarray(valid)] == np.sort(x)).all()

        # the lesson is on disk: a fresh planner (process restart) starts at
        # the learned factor, so its FIRST call already avoids the retry
        restarted = Planner(path)
        assert restarted.capacity_factor_for(key) == cf3
        rec2 = restarted.recorder(n, jnp.int32, mesh)
        slab, valid = cluster_sort(
            jnp.asarray(x), mesh, "x",
            capacity_factor=restarted.capacity_factor_for(key),
            telemetry=rec2, **kw)
        assert restarted.telemetry.last(key).retries == 0
        assert (np.asarray(slab)[np.asarray(valid)] == np.sort(x)).all()

        # the kv twin feeds the same loop
        v = np.arange(n, dtype=np.int32)
        ref = np.argsort(x, kind="stable")
        sk, sv, valid = cluster_sort_kv(
            jnp.asarray(x), jnp.asarray(v), mesh, "x",
            capacity_factor=restarted.capacity_factor_for(key),
            telemetry=rec2, **kw)
        assert restarted.telemetry.last(key).retries == 0
        sk = np.asarray(sk)[np.asarray(valid)]
        sv = np.asarray(sv)[np.asarray(valid)]
        assert (sk == x[ref]).all() and (sv == ref).all()
        print("capacity learning regression ok")
    """)


def test_api_sort_and_sort_kv_close_the_loop_by_default():
    """api.sort / engine.sort_kv on a mesh wire telemetry + learned capacity
    through the default planner automatically — the second skewed call pays
    no retry without the caller doing anything."""
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import sort
        from repro.engine import sort_kv
        from repro.engine.planner import default_planner, plan_key

        mesh = jax.make_mesh((8,), ("x",))
        n = 1024
        rng = np.random.default_rng(0)
        x = rng.integers(0, 3000, n).astype(np.int32)
        kw = dict(mode="range", lo=0, hi=8000)

        planner = default_planner()
        key = plan_key(n, jnp.int32, mesh)
        slab, valid = sort(jnp.asarray(x), mesh=mesh, axis="x", **kw)
        assert (np.asarray(slab)[np.asarray(valid)] == np.sort(x)).all()
        obs = planner.telemetry.last(key)
        assert obs is not None and obs.retries == 1, obs

        slab, valid = sort(jnp.asarray(x), mesh=mesh, axis="x", **kw)
        assert planner.telemetry.last(key).retries == 0
        assert (np.asarray(slab)[np.asarray(valid)] == np.sort(x)).all()

        # sort_kv rides the same default-planner loop (splitters mode here:
        # uniform buckets, no overflow — but telemetry must still record)
        calls_before = planner.telemetry.calls
        k2 = rng.integers(100, 1000, n).astype(np.int32)
        v2 = np.arange(n, dtype=np.int32)
        sk, sv = sort_kv(jnp.asarray(k2), jnp.asarray(v2), mesh=mesh, axis="x")
        ref = np.argsort(k2, kind="stable")
        assert (np.asarray(sk) == k2[ref]).all()
        assert planner.telemetry.calls == calls_before + 1
        assert planner.telemetry.last(key).retries == 0
        print("default-planner closed loop ok")
    """)
