"""Property-based correctness harness for every sort path in the repo.

One oracle: numpy (``np.sort`` / ``np.argsort(kind='stable')``).  One input
generator: random lengths and dtypes crossed with an adversarial case matrix
(duplicate-heavy, pre-sorted, reverse-sorted, all-equal, ±inf floats / int
extremes).  Every path — ``api.sort`` across the paper's models and all
``local_impl`` engines, ``engine.kv`` (sort_kv / argsort / topk), and the
sync + async serving services — must reproduce the oracle exactly.

Runs under real ``hypothesis`` when installed (CI) with a fixed,
derandomized profile so CI stays deterministic; falls back to the seeded
shim in bare containers.
"""
import numpy as np
import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare container — requirements-dev.txt installs the real one
    from _hypothesis_shim import given, settings, strategies as st

from conftest import run_with_devices
from repro.core import sort
from repro.engine import AsyncSortService, SortService, argsort, sort_pairs, topk
from repro.exchange import splitter_bucket, splitters_from_sample

# fixed + derandomized: the same examples on every CI run
settings.register_profile("repro-ci", max_examples=10, deadline=None,
                          derandomize=True)
settings.load_profile("repro-ci")

CASES = ("random", "duplicate_heavy", "sorted", "reverse", "all_equal", "extremes")
DTYPES = ("int32", "float32")
LOCAL_IMPLS = ("xla", "bitonic", "merge", "pallas")

lengths = st.integers(1, 300)
cases = st.sampled_from(CASES)
dtypes = st.sampled_from(DTYPES)
seeds = st.integers(0, 2**20)


def make_keys(case: str, n: int, dtype: str, seed: int) -> np.ndarray:
    """One adversarial (or random) key array, NaN-free by construction."""
    dt = np.dtype(dtype)
    rng = np.random.default_rng(seed)
    if np.issubdtype(dt, np.floating):
        base = (rng.standard_normal(n) * 1e3).astype(dt)
    else:
        base = rng.integers(-10_000, 10_000, n).astype(dt)
    if case == "duplicate_heavy":
        pool = np.asarray([-3, 0, 7, 7, 42], dt)
        base = rng.choice(pool, n)
    elif case == "sorted":
        base = np.sort(base)
    elif case == "reverse":
        base = np.sort(base)[::-1].copy()
    elif case == "all_equal":
        base = np.full(n, base[0], dt)
    elif case == "extremes":
        # ±inf for floats / iinfo extremes for ints: ties against the
        # padding sentinels every padded path uses internally
        if np.issubdtype(dt, np.floating):
            lo, hi = -np.inf, np.inf
        else:
            lo, hi = np.iinfo(dt).min, np.iinfo(dt).max
        base[rng.random(n) < 0.2] = hi
        base[rng.random(n) < 0.2] = lo
    return base


def np_rev(k: np.ndarray) -> np.ndarray:
    """Order-reversing bijection matching engine.kv._rev_key (descending
    stable references: np.argsort(np_rev(k), kind='stable'))."""
    return ~k if np.issubdtype(k.dtype, np.integer) else -k


# one service per module: examples share the compiled-executable cache, so
# the harness exercises the steady state instead of recompiling per example
SERVICE = SortService()
_ASYNC = None


def async_service() -> AsyncSortService:
    global _ASYNC
    if _ASYNC is None:
        _ASYNC = AsyncSortService(SERVICE, max_batch=8, max_delay_ms=1.0)
    return _ASYNC


# --------------------------------------------------------- api.sort (A/B) ---
@given(lengths, cases, dtypes, seeds)
def test_api_sort_shared_models_all_local_impls(n, case, dtype, seed):
    """Models A/B (shared memory) x every local_impl, both directions."""
    x = make_keys(case, n, dtype, seed)
    want = np.sort(x)
    for impl in LOCAL_IMPLS:
        if impl == "pallas" and n > 128:
            continue  # interpret-mode kernel: cap the per-example cost off-TPU
        kw = {"block_n": 64} if impl == "pallas" else {}
        got = sort(jnp.asarray(x), strategy="shared", local_impl=impl,
                   n_threads=4, **kw)
        np.testing.assert_array_equal(np.asarray(got), want, err_msg=impl)
        got = sort(jnp.asarray(x), strategy="shared", local_impl=impl,
                   n_threads=4, ascending=False, **kw)
        np.testing.assert_array_equal(np.asarray(got), want[::-1], err_msg=impl)
    # model A's paper schedule (merge-sort local stage) via its strategy name
    got = sort(jnp.asarray(x), strategy="shared_merge", n_threads=4)
    np.testing.assert_array_equal(np.asarray(got), want)


# ----------------------------------------------------------- engine.kv ------
@given(lengths, cases, dtypes, seeds)
def test_engine_kv_argsort_sortkv_topk(n, case, dtype, seed):
    """sort_kv / argsort / topk == numpy stable references, xla and pallas."""
    k = make_keys(case, n, dtype, seed)
    ref = np.argsort(k, kind="stable")
    refd = np.argsort(np_rev(k), kind="stable")
    v = np.arange(n, dtype=np.int32)
    kt = min(n, 5)
    for impl in ("xla", "pallas"):
        if impl == "pallas" and n > 128:
            continue  # interpret-mode kernel: cap the per-example cost off-TPU
        kw = {"impl": impl, "block_n": 64} if impl == "pallas" else {"impl": impl}
        got = np.asarray(argsort(jnp.asarray(k), **kw))
        np.testing.assert_array_equal(got, ref, err_msg=impl)
        got = np.asarray(argsort(jnp.asarray(k), ascending=False, **kw))
        np.testing.assert_array_equal(got, refd, err_msg=impl)
        sk, sv = sort_pairs(jnp.asarray(k), jnp.asarray(v), **kw)
        np.testing.assert_array_equal(np.asarray(sk), k[ref], err_msg=impl)
        np.testing.assert_array_equal(np.asarray(sv), ref, err_msg=impl)
        vals, idx = topk(jnp.asarray(k), kt, **kw)
        np.testing.assert_array_equal(np.asarray(idx), refd[:kt], err_msg=impl)
        np.testing.assert_array_equal(np.asarray(vals), k[refd[:kt]], err_msg=impl)


# ------------------------------------------- splitter derivation (sample) ---
@given(st.integers(8, 2048), st.integers(2, 32), cases, dtypes, seeds)
def test_splitter_derivation_properties(n, n_buckets, case, dtype, seed):
    """The sample partition's splitter math, against the same case matrix:
    splitters come back sorted and deduplicated, derivation is a pure
    function of the sample, and the induced bucket assignment is total and
    order-compatible with the key order."""
    sample = make_keys(case, n, dtype, seed)
    spl = np.asarray(splitters_from_sample(sample, n_buckets, unique=True))
    again = np.asarray(splitters_from_sample(sample, n_buckets, unique=True))
    np.testing.assert_array_equal(spl, again)      # deterministic
    assert 1 <= len(spl) <= n_buckets - 1
    if len(spl) > 1:
        assert np.all(np.diff(spl) > 0)            # sorted + deduplicated
    # the partition they induce: every key lands in exactly one bucket ...
    keys = make_keys(case, n, dtype, seed + 1)
    b = np.asarray(splitter_bucket(jnp.asarray(keys), jnp.asarray(spl)))
    assert b.shape == keys.shape
    assert b.min() >= 0 and b.max() <= len(spl)
    assert int(np.bincount(b, minlength=len(spl) + 1).sum()) == n
    # ... and the assignment is monotone in the key (order-compatible:
    # concatenating bucket-sorted buckets yields the globally sorted order)
    order = np.argsort(keys, kind="stable")
    assert np.all(np.diff(b[order]) >= 0)


# ------------------------------------------------------------- services -----
@given(st.lists(st.integers(1, 600), min_size=1, max_size=5), cases, dtypes, seeds)
def test_sort_service_ragged_batches(lens, case, dtype, seed):
    """SortService.submit on ragged adversarial batches, every kind."""
    reqs = [make_keys(case, n, dtype, seed + j) for j, n in enumerate(lens)]
    vals = [np.arange(len(r), dtype=np.int32) for r in reqs]
    for r, o in zip(reqs, SERVICE.submit(reqs)):
        np.testing.assert_array_equal(o, np.sort(r))
    for r, o in zip(reqs, SERVICE.submit(reqs, ascending=False)):
        np.testing.assert_array_equal(o, np.sort(r)[::-1])
    for r, o in zip(reqs, SERVICE.submit(reqs, kind="argsort")):
        np.testing.assert_array_equal(o, np.argsort(r, kind="stable"))
    for r, v, (sk, sv) in zip(reqs, vals,
                              SERVICE.submit(reqs, kind="sort_kv", values=vals)):
        ref = np.argsort(r, kind="stable")
        np.testing.assert_array_equal(sk, r[ref])
        np.testing.assert_array_equal(sv, ref)


@given(st.lists(st.integers(1, 600), min_size=1, max_size=5), cases, dtypes, seeds)
def test_async_sort_service_ragged_batches(lens, case, dtype, seed):
    """AsyncSortService futures == the sync oracle, interleaved kinds."""
    svc = async_service()
    reqs = [make_keys(case, n, dtype, seed + j) for j, n in enumerate(lens)]
    futs = [(r, "sort", svc.submit_async(r)) for r in reqs]
    futs += [(r, "argsort", svc.submit_async(r, kind="argsort")) for r in reqs]
    futs += [
        (r, "sort_kv",
         svc.submit_async(r, kind="sort_kv",
                          values=np.arange(len(r), dtype=np.int32)))
        for r in reqs
    ]
    for r, kind, f in futs:
        ref = np.argsort(r, kind="stable")
        if kind == "sort":
            np.testing.assert_array_equal(f.result(timeout=60), np.sort(r))
        elif kind == "argsort":
            np.testing.assert_array_equal(f.result(timeout=60), ref)
        else:
            sk, sv = f.result(timeout=60)
            np.testing.assert_array_equal(sk, r[ref])
            np.testing.assert_array_equal(sv, ref)


# --------------------------------------------- distributed models (C / D) ---
def test_api_sort_distributed_models_case_matrix():
    """The mesh leg of the harness: models C and D through api.sort on a
    forced 8-device mesh, across the same adversarial case matrix."""
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import sort

        mesh = jax.make_mesh((8,), ("x",))
        n = 1024
        def make(case, dtype, seed):
            rng = np.random.default_rng(seed)
            dt = np.dtype(dtype)
            if np.issubdtype(dt, np.floating):
                base = (rng.standard_normal(n) * 1e3).astype(dt)
            else:
                base = rng.integers(-10_000, 10_000, n).astype(dt)
            if case == "duplicate_heavy":
                base = rng.choice(np.asarray([-3, 0, 7, 7, 42], dt), n)
            elif case == "sorted":
                base = np.sort(base)
            elif case == "reverse":
                base = np.sort(base)[::-1].copy()
            elif case == "all_equal":
                base = np.full(n, base[0], dt)
            return base

        cases = ("random", "duplicate_heavy", "sorted", "reverse", "all_equal")
        for dtype in ("int32", "float32"):
            for ci, case in enumerate(cases):
                x = make(case, dtype, seed=100 + ci)
                want = np.sort(x)
                for impl in ("xla", "merge"):   # model C: ppermute merge tree
                    got = sort(jnp.asarray(x), strategy="distributed_merge",
                               mesh=mesh, axis="x", local_impl=impl)
                    assert (np.asarray(got) == want).all(), ("C", impl, case, dtype)
                for impl in ("xla", "bitonic", "pallas"):  # model D: cluster
                    kw = {"block_n": 64} if impl == "pallas" else {}
                    slab, valid = sort(jnp.asarray(x), strategy="cluster",
                                       mesh=mesh, axis="x", local_impl=impl, **kw)
                    got = np.asarray(slab)[np.asarray(valid)]
                    assert (got == want).all(), ("D", impl, case, dtype)
                # model D again across both partition families (PR 8): the
                # auto-ranged radix and the composite-splitter sample modes
                # must match the oracle on every adversarial case too
                # (explicit capacity_factor= keeps the fuzz out of the
                # process-wide capacity-learning loop)
                for mode in ("radix", "sample"):
                    slab, valid = sort(jnp.asarray(x), strategy="cluster",
                                       mesh=mesh, axis="x", mode=mode,
                                       capacity_factor=2.0)
                    got = np.asarray(slab)[np.asarray(valid)]
                    assert (got == want).all(), ("D", mode, case, dtype)
        print("C/D case matrix ok")
    """)
