"""Per-arch smoke tests: reduced same-family config, one forward + one train
step on CPU, asserting output shapes and finiteness (assignment requirement)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCHS, reduced
from repro.models.transformer import forward, model_init
from repro.optim.adamw import OptConfig, init_opt_state
from repro.train.steps import train_step

ARCH_IDS = sorted(ARCHS)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = reduced(ARCHS[arch])
    key = jax.random.PRNGKey(0)
    params = model_init(key, cfg)
    rng = np.random.default_rng(0)
    B, S = 2, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    fe = None
    if cfg.frontend != "none":
        fe = jnp.asarray(
            rng.standard_normal((B, cfg.n_frontend_tokens, cfg.d_model)), jnp.float32
        )

    logits, stats = forward(params, cfg, toks, frontend_embeds=fe, remat=False)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all(), arch

    ocfg = OptConfig(peak_lr=1e-3, warmup_steps=2, total_steps=10)
    opt = init_opt_state(params, ocfg)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if fe is not None:
        batch["frontend_embeds"] = fe
    p2, opt2, metrics = jax.jit(
        functools.partial(train_step, cfg=cfg, opt_cfg=ocfg, loss_chunk=8)
    )(params, opt, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert np.isfinite(float(metrics["grad_norm"])), arch
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
        if a.dtype in (jnp.float32, jnp.bfloat16)
    )
    assert moved, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_configs_match_published_sizes(arch):
    expect = {
        "dbrx-132b": 132, "granite-moe-3b-a800m": 3.3, "internvl2-2b": 1.8,
        "qwen3-0.6b": 0.6, "command-r-35b": 32, "qwen2-7b": 7.1,
        "gemma3-12b": 12, "musicgen-medium": 1.4, "mamba2-1.3b": 1.3,
        "jamba-1.5-large-398b": 398,
    }[arch]
    got = ARCHS[arch].param_count() / 1e9
    assert 0.75 * expect <= got <= 1.25 * expect, (arch, got, expect)


def test_active_params_moe():
    assert ARCHS["dbrx-132b"].active_param_count() / 1e9 == pytest.approx(36, rel=0.1)
    assert ARCHS["jamba-1.5-large-398b"].active_param_count() / 1e9 == pytest.approx(94, rel=0.1)
