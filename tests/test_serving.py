"""Serving-path correctness: prefill == forward; decode continues prefill."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.transformer import ModelConfig, forward, model_init
from repro.train.steps import prefill_step, serve_decode_step

KEY = jax.random.PRNGKey(0)
RNG = np.random.default_rng(0)

FAMILIES = {
    "dense": ModelConfig("d", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                         head_dim=8, d_ff=64, vocab_size=64, qk_norm=True,
                         qkv_bias=True, param_dtype=jnp.float32,
                         compute_dtype=jnp.float32, kv_chunk=8),
    "ssm": ModelConfig("s", n_layers=2, d_model=32, n_heads=0, n_kv_heads=0,
                       head_dim=0, d_ff=0, vocab_size=64, pattern=("mamba",),
                       ffn_pattern=(None,), ssm_state=16, ssm_head_dim=8,
                       ssm_chunk=4, param_dtype=jnp.float32, compute_dtype=jnp.float32),
    "local": ModelConfig("l", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                         head_dim=8, d_ff=64, vocab_size=64,
                         pattern=("attn_l", "attn"), ffn_pattern=("dense", "dense"),
                         sliding_window=4, param_dtype=jnp.float32,
                         compute_dtype=jnp.float32, kv_chunk=4),
    "moe": ModelConfig("m", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                       head_dim=8, d_ff=16, vocab_size=64, pattern=("attn",),
                       ffn_pattern=("moe",), n_experts=4, top_k=2,
                       capacity_factor=8.0, param_dtype=jnp.float32,
                       compute_dtype=jnp.float32, kv_chunk=8),
    "hybrid": ModelConfig("h", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                          head_dim=8, d_ff=16, vocab_size=64,
                          pattern=("attn", "mamba"), ffn_pattern=("moe", "dense"),
                          n_experts=4, top_k=2, capacity_factor=8.0, ssm_state=16,
                          ssm_head_dim=8, ssm_chunk=4, param_dtype=jnp.float32,
                          compute_dtype=jnp.float32, kv_chunk=8),
}


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_prefill_matches_forward_and_decode_continues(family):
    cfg = FAMILIES[family]
    params = model_init(KEY, cfg)
    S = 12
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (2, S)), jnp.int32)

    logits_full, _ = forward(params, cfg, toks, remat=False)
    last, cache = prefill_step(params, cfg, toks, cache_len=S + 4)
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(logits_full[:, -1]), atol=2e-3, rtol=1e-3
    )

    # three decode steps vs fresh full forwards
    cur = toks
    for _ in range(3):
        nxt = jnp.asarray(RNG.integers(0, cfg.vocab_size, (2, 1)), jnp.int32)
        lg, cache = serve_decode_step(params, cfg, nxt, cache)
        cur = jnp.concatenate([cur, nxt], axis=1)
        ref, _ = forward(params, cfg, cur, remat=False)
        np.testing.assert_allclose(
            np.asarray(lg[:, 0]), np.asarray(ref[:, -1]), atol=5e-3, rtol=1e-3
        )


def test_decode_from_scratch_matches_forward():
    cfg = FAMILIES["dense"]
    params = model_init(KEY, cfg)
    from repro.models.transformer import decode_step, init_cache

    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (2, 6)), jnp.int32)
    cache = init_cache(cfg, 2, 16)
    outs = []
    for t in range(6):
        lg, cache = decode_step(params, cfg, toks[:, t : t + 1], cache)
        outs.append(lg)
    ref, _ = forward(params, cfg, toks, remat=False)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(outs, 1)), np.asarray(ref), atol=2e-3, rtol=1e-3
    )
