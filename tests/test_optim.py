"""Optimizer: schedule, quantized states, gradient compression, convergence."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare container — requirements-dev.txt installs the real one
    from _hypothesis_shim import given, settings, strategies as st

from repro.models.transformer import ModelConfig, model_init
from repro.optim.adamw import (
    OptConfig,
    apply_updates,
    dequantize_blockwise,
    init_opt_state,
    lr_at,
    quantize_blockwise,
)
from repro.train.steps import train_step

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def test_lr_schedule_shape():
    cfg = OptConfig(peak_lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(lr_at(cfg, 0)) == 0.0
    assert float(lr_at(cfg, 10)) == pytest.approx(1.0, abs=1e-6)
    assert float(lr_at(cfg, 100)) == pytest.approx(0.1, abs=1e-6)
    assert float(lr_at(cfg, 55)) < 1.0


@given(st.lists(st.floats(-100, 100, allow_nan=False, width=32), min_size=2, max_size=300))
def test_quantize_roundtrip_error_bound(xs):
    x = jnp.asarray(np.asarray(xs, np.float32).reshape(1, -1))
    qs = quantize_blockwise(x)
    back = dequantize_blockwise(qs, x)
    scale = np.abs(np.asarray(x)).max(-1)
    assert np.abs(np.asarray(back) - np.asarray(x)).max() <= scale / 127.0 * 0.51 + 1e-7


def _tiny():
    cfg = ModelConfig("t", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, head_dim=8,
                      d_ff=64, vocab_size=64, param_dtype=jnp.float32,
                      compute_dtype=jnp.float32, kv_chunk=8)
    return cfg, model_init(jax.random.PRNGKey(0), cfg)


@pytest.mark.parametrize("state_dtype,compress", [("f32", False), ("int8", False),
                                                  ("f32", True), ("int8", True)])
def test_training_converges_all_variants(state_dtype, compress):
    cfg, params = _tiny()
    ocfg = OptConfig(peak_lr=1e-2, warmup_steps=5, total_steps=60,
                     state_dtype=state_dtype, compress_grads=compress)
    opt = init_opt_state(params, ocfg)
    step = jax.jit(functools.partial(train_step, cfg=cfg, opt_cfg=ocfg, loss_chunk=8))
    rng = np.random.default_rng(0)
    losses = []
    for _ in range(25):
        toks = (rng.integers(0, 32, size=(4, 17)) * 2).astype(np.int32) % 64
        batch = {"tokens": jnp.asarray(toks[:, :-1]), "labels": jnp.asarray(toks[:, 1:])}
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 5, (state_dtype, compress, losses[0], losses[-1])


def test_int8_matches_f32_trajectory_closely():
    cfg, params0 = _tiny()
    rng = np.random.default_rng(0)
    batches = []
    for _ in range(15):
        toks = (rng.integers(0, 32, size=(4, 17)) * 2).astype(np.int32) % 64
        batches.append({"tokens": jnp.asarray(toks[:, :-1]), "labels": jnp.asarray(toks[:, 1:])})

    final = {}
    for sd in ("f32", "int8"):
        params = jax.tree.map(lambda x: x, params0)
        ocfg = OptConfig(peak_lr=5e-3, warmup_steps=3, total_steps=30, state_dtype=sd)
        opt = init_opt_state(params, ocfg)
        step = jax.jit(functools.partial(train_step, cfg=cfg, opt_cfg=ocfg, loss_chunk=8))
        for b in batches:
            params, opt, m = step(params, opt, b)
        final[sd] = float(m["loss"])
    assert abs(final["int8"] - final["f32"]) < 0.25 * final["f32"], final


def test_grad_clipping_applies():
    cfg, params = _tiny()
    ocfg = OptConfig(peak_lr=1e-3, clip_norm=1e-6, warmup_steps=1, total_steps=10)
    opt = init_opt_state(params, ocfg)
    g = jax.tree.map(lambda p: jnp.ones_like(p, jnp.float32), params)
    p2, _, m = apply_updates(params, g, opt, ocfg)
    # with a vanishing clip norm the update reduces to ~weight decay only
    delta = max(
        float(jnp.abs(a - b).max())
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))
    )
    assert delta < 1e-3
    assert float(m["grad_norm"]) > 1.0
