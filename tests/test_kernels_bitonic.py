"""Pallas bitonic kernels vs pure-jnp oracles (interpret mode, shape sweep)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bitonic_sort import ref
from repro.kernels.bitonic_sort.bitonic_sort import block_merge, block_sort, global_stage
from repro.kernels.bitonic_sort.ops import pallas_argsort, pallas_sort, pallas_sort_kv

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("block_n,n", [(64, 64), (64, 512), (128, 128), (128, 1024), (256, 2048)])
@pytest.mark.parametrize("dtype", [np.float32, np.int32, np.float16])
def test_block_sort_kernel_vs_ref(block_n, n, dtype):
    x = (RNG.standard_normal(n) * 1000).astype(dtype)
    got = np.asarray(block_sort(jnp.asarray(x), block_n, interpret=True))
    want = np.asarray(ref.block_sort_ref(jnp.asarray(x), block_n))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("block_n,n,k", [(64, 256, 128), (64, 256, 256), (128, 512, 256)])
def test_block_merge_kernel_vs_ref(block_n, n, k):
    # prepare a state consistent with stage k: run ref network up to this point
    x = (RNG.standard_normal(n) * 100).astype(np.float32)
    y = ref.block_sort_ref(jnp.asarray(x), block_n)
    kk = 2 * block_n
    while kk <= k:
        j = kk // 2
        while j >= block_n:
            y = ref.global_stage_ref(y, j, kk)
            j //= 2
        got = np.asarray(block_merge(y, block_n, kk, interpret=True))
        want = np.asarray(ref.block_merge_ref(y, block_n, kk))
        np.testing.assert_array_equal(got, want)
        y = want
        kk *= 2


@pytest.mark.parametrize("block_n,n", [(64, 128), (64, 1024), (128, 4096), (256, 16384), (1024, 8192)])
@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_pallas_sort_end_to_end(block_n, n, dtype):
    x = (RNG.standard_normal(n) * 10_000).astype(dtype)
    got = np.asarray(pallas_sort(jnp.asarray(x), block_n=block_n))
    np.testing.assert_array_equal(got, np.asarray(ref.full_sort_ref(jnp.asarray(x))))


def test_pallas_sort_bf16():
    x = jnp.asarray(RNG.standard_normal(1024), jnp.bfloat16)
    got = pallas_sort(x, block_n=128)
    np.testing.assert_array_equal(
        np.asarray(got, np.float32), np.asarray(jnp.sort(x), np.float32)
    )


def test_pallas_sort_rejects_bad_shapes():
    with pytest.raises(ValueError):
        pallas_sort(jnp.zeros((2, 4)))  # not 1-D
    with pytest.raises(ValueError):
        pallas_sort(jnp.zeros(16), block_n=48)  # block_n not a power of two
    with pytest.raises(ValueError):
        pallas_argsort(jnp.zeros((2, 4)))


@pytest.mark.parametrize("n", [1, 2, 3, 100, 500, 1000])
def test_pallas_sort_any_length(n):
    """Regression: non-pow2 and n < block_n both used to raise — the modulo
    check fired before the block_n clamp. Any length >= 1 must now work."""
    x = (RNG.standard_normal(n) * 1000).astype(np.int32)
    got = np.asarray(pallas_sort(jnp.asarray(x), block_n=256))
    np.testing.assert_array_equal(got, np.sort(x))


def test_pallas_sort_padding_with_sentinel_valued_keys():
    """Keys equal to the pad sentinel must survive (pads can only displace
    equal keys, and only beyond the sliced prefix)."""
    x = np.array([5, np.iinfo(np.int32).max, 1], np.int32)
    got = np.asarray(pallas_sort(jnp.asarray(x), block_n=64))
    np.testing.assert_array_equal(got, np.sort(x))


@pytest.mark.parametrize("n", [7, 100, 256, 777])
def test_pallas_argsort_matches_numpy_stable(n):
    x = RNG.integers(0, 7, n).astype(np.int32)  # duplicate-heavy: stability matters
    x[0] = np.iinfo(np.int32).max  # and a key equal to the pad sentinel
    got = np.asarray(pallas_argsort(jnp.asarray(x), block_n=64))
    np.testing.assert_array_equal(got, np.argsort(x, kind="stable"))


def test_pallas_sort_kv_roundtrip():
    k = (RNG.standard_normal(333) * 10).astype(np.float32)
    v = {"a": RNG.standard_normal((333, 2)).astype(np.float32),
         "i": np.arange(333, dtype=np.int32)}
    sk, sv = pallas_sort_kv(jnp.asarray(k), jax.tree.map(jnp.asarray, v), block_n=128)
    ref_ord = np.argsort(k, kind="stable")
    np.testing.assert_array_equal(np.asarray(sk), k[ref_ord])
    np.testing.assert_array_equal(np.asarray(sv["a"]), v["a"][ref_ord])
    np.testing.assert_array_equal(np.asarray(sv["i"]), v["i"][ref_ord])
