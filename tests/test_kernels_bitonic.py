"""Pallas bitonic kernels vs pure-jnp oracles (interpret mode, shape sweep)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bitonic_sort import ref
from repro.kernels.bitonic_sort.bitonic_sort import block_merge, block_sort, global_stage
from repro.kernels.bitonic_sort.ops import pallas_sort

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("block_n,n", [(64, 64), (64, 512), (128, 128), (128, 1024), (256, 2048)])
@pytest.mark.parametrize("dtype", [np.float32, np.int32, np.float16])
def test_block_sort_kernel_vs_ref(block_n, n, dtype):
    x = (RNG.standard_normal(n) * 1000).astype(dtype)
    got = np.asarray(block_sort(jnp.asarray(x), block_n, interpret=True))
    want = np.asarray(ref.block_sort_ref(jnp.asarray(x), block_n))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("block_n,n,k", [(64, 256, 128), (64, 256, 256), (128, 512, 256)])
def test_block_merge_kernel_vs_ref(block_n, n, k):
    # prepare a state consistent with stage k: run ref network up to this point
    x = (RNG.standard_normal(n) * 100).astype(np.float32)
    y = ref.block_sort_ref(jnp.asarray(x), block_n)
    kk = 2 * block_n
    while kk <= k:
        j = kk // 2
        while j >= block_n:
            y = ref.global_stage_ref(y, j, kk)
            j //= 2
        got = np.asarray(block_merge(y, block_n, kk, interpret=True))
        want = np.asarray(ref.block_merge_ref(y, block_n, kk))
        np.testing.assert_array_equal(got, want)
        y = want
        kk *= 2


@pytest.mark.parametrize("block_n,n", [(64, 128), (64, 1024), (128, 4096), (256, 16384), (1024, 8192)])
@pytest.mark.parametrize("dtype", [np.float32, np.int32])
def test_pallas_sort_end_to_end(block_n, n, dtype):
    x = (RNG.standard_normal(n) * 10_000).astype(dtype)
    got = np.asarray(pallas_sort(jnp.asarray(x), block_n=block_n))
    np.testing.assert_array_equal(got, np.asarray(ref.full_sort_ref(jnp.asarray(x))))


def test_pallas_sort_bf16():
    x = jnp.asarray(RNG.standard_normal(1024), jnp.bfloat16)
    got = pallas_sort(x, block_n=128)
    np.testing.assert_array_equal(
        np.asarray(got, np.float32), np.asarray(jnp.sort(x), np.float32)
    )


def test_pallas_sort_rejects_bad_shapes():
    with pytest.raises(ValueError):
        pallas_sort(jnp.zeros((2, 4)))
    with pytest.raises(ValueError):
        pallas_sort(jnp.zeros(100))  # not a power of two
