"""Property + unit tests for the paper's sort models (single device)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # bare container — requirements-dev.txt installs the real one
    from _hypothesis_shim import given, settings, strategies as st

from repro.core import (
    bitonic_merge_pair,
    bitonic_sort,
    bitonic_topk,
    merge_adjacent,
    merge_sorted_pair,
    nonrecursive_merge_sort,
    recursive_merge_sort_host,
    shared_memory_sort,
)

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")

ints = st.lists(st.integers(-10_000, 10_000), min_size=1, max_size=300)


# ------------------------------------------------------------- properties ---
@given(ints)
def test_bitonic_sorts_and_permutes(xs):
    x = np.asarray(xs, np.int32)
    out = np.asarray(bitonic_sort(jnp.asarray(x)))
    assert (out == np.sort(x)).all()  # sortedness + permutation in one


@given(ints)
def test_bitonic_descending(xs):
    x = np.asarray(xs, np.int32)
    out = np.asarray(bitonic_sort(jnp.asarray(x), ascending=False))
    assert (out == -np.sort(-x)).all()


@given(st.lists(st.integers(0, 7), min_size=1, max_size=200))
def test_bitonic_stability(xs):
    """Stable sort: payload order within equal keys == original order."""
    x = np.asarray(xs, np.int32)
    idx = np.arange(len(x), dtype=np.int32)
    k, v = bitonic_sort(jnp.asarray(x), jnp.asarray(idx), stable=True)
    ref = np.argsort(x, kind="stable")
    assert (np.asarray(v) == ref).all()
    assert (np.asarray(k) == x[ref]).all()


@given(ints)
def test_nonrecursive_merge_sort_matches_paper_semantics(xs):
    x = np.asarray(xs, np.int32)
    assert (np.asarray(nonrecursive_merge_sort(jnp.asarray(x))) == np.sort(x)).all()


@given(st.integers(1, 4), ints)
def test_shared_memory_sort_all_impls(log_t, xs):
    x = np.asarray(xs, np.int32)
    t = 1 << log_t
    for impl in ("xla", "bitonic", "merge"):
        out = np.asarray(shared_memory_sort(jnp.asarray(x), n_threads=t, local_impl=impl))
        assert (out == np.sort(x)).all(), impl


@given(st.lists(st.floats(-1e6, 1e6, allow_nan=False, width=32), min_size=1, max_size=128),
       st.lists(st.floats(-1e6, 1e6, allow_nan=False, width=32), min_size=1, max_size=128))
def test_merge_sorted_pair_stable_merge(a, b):
    n = min(len(a), len(b))
    a = np.sort(np.asarray(a[:n], np.float32))
    b = np.sort(np.asarray(b[:n], np.float32))
    out = np.asarray(merge_sorted_pair(jnp.asarray(a), jnp.asarray(b)))
    assert np.allclose(out, np.sort(np.concatenate([a, b])))


# ------------------------------------------------------------------ units ---
def test_recursive_host_reference():
    rng = np.random.default_rng(0)
    x = rng.integers(0, 1000, size=(4, 37)).astype(np.int64)
    assert (recursive_merge_sort_host(x) == np.sort(x, -1)).all()


def test_bitonic_merge_pair_pow2_only():
    with pytest.raises(ValueError):
        bitonic_merge_pair(jnp.zeros(3), jnp.zeros(3))


def test_merge_adjacent_round():
    rng = np.random.default_rng(1)
    x = rng.integers(0, 100, size=(64,)).astype(np.int32)
    x4 = np.sort(x.reshape(-1, 16), axis=-1).reshape(-1)  # sorted runs of 16
    out = np.asarray(merge_adjacent(jnp.asarray(x4), 16))
    expect = np.sort(x.reshape(-1, 32), -1).reshape(-1)
    assert (out == expect).all()


def test_bitonic_topk_matches_lax():
    rng = np.random.default_rng(2)
    x = rng.standard_normal((5, 64)).astype(np.float32)
    vals, idx = bitonic_topk(jnp.asarray(x), 8)
    lv, li = jax.lax.top_k(jnp.asarray(x), 8)
    assert np.allclose(np.asarray(vals), np.asarray(lv))
    assert np.allclose(np.take_along_axis(x, np.asarray(idx), -1), np.asarray(lv))


def test_batched_leading_dims():
    rng = np.random.default_rng(3)
    x = rng.standard_normal((2, 3, 50)).astype(np.float32)
    out = np.asarray(bitonic_sort(jnp.asarray(x)))
    assert np.allclose(out, np.sort(x, -1))


# -------------------------------------------------- pallas local_impl path ---
# interpret mode off-TPU: small sizes/block_n keep the per-shape compiles cheap
@pytest.mark.parametrize("n", [1, 100, 256, 700])  # non-pow2 included
@pytest.mark.parametrize("n_threads", [2, 8])
def test_shared_memory_sort_pallas_impl(n, n_threads):
    rng = np.random.default_rng(6)
    x = rng.integers(-10_000, 10_000, n).astype(np.int32)
    out = shared_memory_sort(
        jnp.asarray(x), n_threads=n_threads, local_impl="pallas", block_n=64
    )
    assert (np.asarray(out) == np.sort(x)).all()


def test_shared_memory_sort_pallas_batched_and_descending():
    rng = np.random.default_rng(7)
    x = rng.standard_normal((2, 3, 100)).astype(np.float32)
    out = shared_memory_sort(jnp.asarray(x), n_threads=4, local_impl="pallas", block_n=64)
    assert np.allclose(np.asarray(out), np.sort(x, -1))
    out = shared_memory_sort(
        jnp.asarray(x), n_threads=4, local_impl="pallas", block_n=64, ascending=False
    )
    assert np.allclose(np.asarray(out), np.sort(x, -1)[..., ::-1])


def test_fast_local_sort_pallas_matches_xla():
    from repro.core import fast_local_sort

    rng = np.random.default_rng(8)
    x = rng.integers(0, 100, (4, 130)).astype(np.int32)  # batched, non-pow2
    got = fast_local_sort(jnp.asarray(x), impl="pallas", block_n=64)
    assert (np.asarray(got) == np.sort(x, -1)).all()
    got = fast_local_sort(jnp.asarray(x), impl="pallas", block_n=64, ascending=False)
    assert (np.asarray(got) == np.sort(x, -1)[..., ::-1]).all()
    with pytest.raises(ValueError):
        fast_local_sort(jnp.asarray(x), impl="nope")
