"""Models C & D on an 8-device mesh (paper §3.3/§3.4), incl. the paper-faithful
decimal MSD mode and the beyond-paper sample-splitter mode under skew.

    python examples/distributed_sort_demo.py          # sets its own XLA_FLAGS
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import cluster_sort, distributed_merge_sort

mesh = jax.make_mesh((8,), ("nodes",))
rng = np.random.default_rng(0)
x = rng.integers(100, 1000, size=80_000).astype(np.int32)
xj = jnp.asarray(x)
want = np.sort(x)

# model C — distributed merge tree (MPI Fig 3 -> ppermute rounds)
out = distributed_merge_sort(xj, mesh, "nodes")
assert (np.asarray(out) == want).all()
print("model C  distributed merge tree      OK   (root holds all data — the")
print("         paper's own scaling flaw, kept as the faithful baseline)")

# model D — one-step MSD-radix scatter + local sort (zero inter-node merging)
slab, valid = cluster_sort(xj, mesh, "nodes", mode="decimal", digits=3)
assert (np.asarray(slab)[np.asarray(valid)] == want).all()
print("model D  decimal MSD (paper-exact)   OK   (result stays distributed)")

# beyond paper: sample splitters keep buckets balanced under heavy skew
skewed = (rng.zipf(1.5, size=80_000) % 900 + 100).astype(np.int32)
slab, valid = cluster_sort(jnp.asarray(skewed), mesh, "nodes", mode="splitters")
assert (np.asarray(slab)[np.asarray(valid)] == np.sort(skewed)).all()
print("model D+ sample splitters (skewed)   OK")
