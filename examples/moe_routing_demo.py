"""The flagship integration: MoE token dispatch IS the paper's model D.

Shows, on an 8-device (data x model) mesh, that expert routing through the
unified exchange layer (``repro.exchange.partition_exchange`` /
``combine_exchange`` — the same two calls ``core/cluster_sort.py`` sorts
with) (a) groups tokens per expert in *stable* arrival order — the property
the paper chose merge sort for — (b) reconstructs the exact dense-MoE
output, and (c) closes the adaptive capacity loop: a skewed router pays its
overflow retry exactly once, then serves at the learned expert capacity
factor (docs/exchange.md).

    python examples/moe_routing_demo.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.exchange import partition_exchange, combine_exchange
from repro.engine import Planner, argsort, sort_kv
from repro.models.moe import (
    MoEConfig,
    collapse_router,
    moe_apply_adaptive,
    moe_apply_ep_replicated,
    moe_init,
    moe_plan_key,
)

mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)

# --- raw dispatch: tokens keyed by expert id, one all_to_all each way -------
E, T, D = 4, 64, 8
expert_of = jnp.asarray(rng.integers(0, E, T), jnp.int32)
tokens = jnp.asarray(np.arange(T * D, dtype=np.float32).reshape(T, D))


def body(keys, vals):
    ex = partition_exchange(keys, vals, keys, "model", capacity=T, n_buckets=E)
    # each shard now owns every token routed to its experts, grouped stably;
    # "process" = tag with the receiving shard id, then send everything back
    tagged = ex.recv_values + jax.lax.axis_index("model") * 1000.0
    back = combine_exchange(tagged, ex, "model")
    return back


out = jax.jit(
    jax.shard_map(
        body, mesh=mesh,
        in_specs=(P(("data", "model")), P(("data", "model"))),
        out_specs=P(("data", "model")),
    )
)(expert_of, tokens)

shard_tag = np.asarray(out)[:, 0] // 1000
expected_shard = np.asarray(expert_of) * 4 // E  # contiguous bucket->shard map
assert (shard_tag == expected_shard).all()
assert np.allclose(np.asarray(out) % 1000, np.asarray(tokens) % 1000)
print("dispatch: every token visited exactly its expert's shard and returned ✓")

# --- record sort: the engine sorts (key, payload) pairs across the mesh -----
# same primitive, now as a user-facing API: tokens (the values) follow their
# routing keys through the one all_to_all, stably — engine.sort_kv/argsort.
smesh = jax.make_mesh((8,), ("nodes",))
n = 4096
rec_keys = jnp.asarray(rng.integers(0, 1000, n), jnp.int32)
rec_payload = jnp.asarray(rng.standard_normal((n, 8)), jnp.float32)
sk, sv = sort_kv(rec_keys, {"tok": rec_payload}, mesh=smesh, axis="nodes")
ref = np.argsort(np.asarray(rec_keys), kind="stable")
assert (np.asarray(sk) == np.asarray(rec_keys)[ref]).all()
assert (np.asarray(sv["tok"]) == np.asarray(rec_payload)[ref]).all()
idx = argsort(rec_keys, mesh=smesh, axis="nodes")
assert (np.asarray(idx) == ref).all()
print("engine: distributed sort_kv/argsort == np.argsort(stable) reference ✓")

# --- full MoE layer equals the dense computation ----------------------------
cfg = MoEConfig(d_model=16, d_ff=8, n_experts=4, top_k=2, capacity_factor=8.0)
p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32, ep_shards=1)
x = jax.random.normal(jax.random.PRNGKey(1), (32, 16))
y, aux, overflow = moe_apply_ep_replicated(p, cfg, x)
print(f"MoE layer: aux_loss={float(aux):.3f} overflow={bool(overflow)} "
      f"out_norm={float(jnp.linalg.norm(y)):.2f} ✓")

# --- adaptive capacity learning over the same layer --------------------------
# concentrate the router on a few hot experts and start from a lean capacity
# factor: the first adaptive call overflows, retries, and teaches the planner
# a learned factor for this (n_experts, top_k, token-bucket) cell; the second
# call — and, via the JSON plan cache, every restarted process — pays zero.
acfg = cfg._replace(capacity_factor=1.0)
skewed = collapse_router(p, 8.0)
planner = Planner()  # in-memory; give it a path to persist across restarts
cell = moe_plan_key(x.shape[0], acfg, x.dtype)
y1, _, counts = moe_apply_adaptive(skewed, acfg, x, planner=planner)
first = planner.telemetry.last(cell)
y2, _, _ = moe_apply_adaptive(skewed, acfg, x, planner=planner)
assert first.retries > 0 and planner.telemetry.last(cell).retries == 0
assert np.allclose(np.asarray(y1), np.asarray(y2))
print(f"adaptive: skewed router paid {first.retries} retrie(s) once, learned "
      f"cf={planner.capacity_factor_for(cell, default=acfg.capacity_factor):.2f} "
      f"(counts={np.asarray(counts).tolist()}), steady state pays zero ✓")
