"""Quickstart: the paper's four sort models + the Pallas kernel, in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (
    bitonic_sort,
    nonrecursive_merge_sort,
    shared_memory_sort,
    sort,
)
from repro.kernels.bitonic_sort.ops import pallas_sort

rng = np.random.default_rng(0)
x = rng.integers(100, 1000, size=100_000).astype(np.int32)  # paper's 3-digit keys
xj = jnp.asarray(x)
want = np.sort(x)

# model A — shared-memory non-recursive merge sort (paper §3.2)
out = sort(xj, strategy="shared_merge", n_threads=8)
assert (np.asarray(out) == want).all()
print("model A  shared non-recursive merge  OK")

# model B — shared-memory hybrid quicksort+merge (paper §3.2, the winner)
out = sort(xj, strategy="shared_hybrid", n_threads=8)
assert (np.asarray(out) == want).all()
print("model B  shared hybrid quick+merge   OK")

# the building blocks are first-class too
assert (np.asarray(nonrecursive_merge_sort(xj)) == want).all()
assert (np.asarray(bitonic_sort(jnp.asarray(x[:4096]))) == np.sort(x[:4096])).all()

# the Pallas TPU kernel (interpret mode on CPU), element-exact vs jnp.sort
k = pallas_sort(jnp.asarray(x[:65536]), block_n=1024)
assert (np.asarray(k) == np.sort(x[:65536])).all()
print("Pallas   VMEM bitonic kernel         OK")

# the engine sorts records, not just keys: sort_kv carries any values pytree
# along with the keys (stable — equal keys keep arrival order)
from repro.engine import sort_kv, argsort, SortService

payload = {"row": jnp.arange(xj.shape[0]), "feat": jnp.ones((xj.shape[0], 4))}
sk, sv = sort_kv(xj, payload)
order = np.argsort(x, kind="stable")
assert (np.asarray(sk) == want).all() and (np.asarray(sv["row"]) == order).all()
assert (np.asarray(argsort(xj)) == order).all()
print("engine   sort_kv / argsort           OK")

# the serving front door: ragged batches, shape-bucketed, zero re-traces
svc = SortService()
outs = svc.submit([x[:1000], x[:800], x[:500]])
assert all((o == np.sort(x[:n])).all() for o, n in zip(outs, (1000, 800, 500)))
svc.submit([x[:900], x[:700]])  # same buckets -> zero new compilations
assert svc.cache.stats()["misses"] == 2  # one executable per (1024,) / (512,)
print("engine   SortService bucket cache    OK")

# models C and D need a multi-device mesh — see examples/distributed_sort_demo.py
print("\nfor models C/D run: python examples/distributed_sort_demo.py")
