"""Quickstart: the paper's four sort models + the Pallas kernel, in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (
    bitonic_sort,
    nonrecursive_merge_sort,
    shared_memory_sort,
    sort,
)
from repro.kernels.bitonic_sort.ops import pallas_sort

rng = np.random.default_rng(0)
x = rng.integers(100, 1000, size=100_000).astype(np.int32)  # paper's 3-digit keys
xj = jnp.asarray(x)
want = np.sort(x)

# model A — shared-memory non-recursive merge sort (paper §3.2)
out = sort(xj, strategy="shared_merge", n_threads=8)
assert (np.asarray(out) == want).all()
print("model A  shared non-recursive merge  OK")

# model B — shared-memory hybrid quicksort+merge (paper §3.2, the winner)
out = sort(xj, strategy="shared_hybrid", n_threads=8)
assert (np.asarray(out) == want).all()
print("model B  shared hybrid quick+merge   OK")

# the building blocks are first-class too
assert (np.asarray(nonrecursive_merge_sort(xj)) == want).all()
assert (np.asarray(bitonic_sort(jnp.asarray(x[:4096]))) == np.sort(x[:4096])).all()

# the Pallas TPU kernel (interpret mode on CPU), element-exact vs jnp.sort
k = pallas_sort(jnp.asarray(x[:65536]), block_n=1024)
assert (np.asarray(k) == np.sort(x[:65536])).all()
print("Pallas   VMEM bitonic kernel         OK")

# models C and D need a multi-device mesh — see examples/distributed_sort_demo.py
print("\nfor models C/D run: python examples/distributed_sort_demo.py")
