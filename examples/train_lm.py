"""End-to-end training example: a ~20M-param qwen3-family model for 150 steps
on CPU with checkpoint/restart (the full-size configs lower via the dry-run;
this exercises the same driver end to end).

    PYTHONPATH=src python examples/train_lm.py            # ~20M, 150 steps
    PYTHONPATH=src python examples/train_lm.py --tiny     # smoke (seconds)
    PYTHONPATH=src python examples/train_lm.py --moe      # tiny MoE LM on a
        # forced expert-parallel mesh, skewed router: exercises the
        # between-step capacity-learning loop end to end (CI train-smoke);
        # point $REPRO_SORT_PLANS at a file to persist the learned factor
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"))

import argparse

from repro.launch.train import main as train_main

ap = argparse.ArgumentParser()
ap.add_argument("--tiny", action="store_true")
ap.add_argument("--moe", action="store_true")
ap.add_argument("--steps", type=int, default=None)
args = ap.parse_args()

if args.moe:
    import math
    from dataclasses import replace

    import jax
    import jax.numpy as jnp

    from repro.configs.base import ARCHS

    cfg = replace(
        ARCHS["qwen3-0.6b"],
        name="qwen3-moe-tiny",
        n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, head_dim=16,
        d_ff=32, vocab_size=128, kv_chunk=16,
        pattern=("attn",), ffn_pattern=("moe",),
        # cf=1.0 on a collapsed router guarantees step-1 overflow — the
        # capacity loop must visibly learn (and persist) a higher factor
        n_experts=8, top_k=2, capacity_factor=1.0,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )
    ARCHS["qwen3-moe-tiny"] = cfg  # register for the driver
    n_dev = len(jax.devices())
    mesh = ["--mesh", "data=2,model=4"] if n_dev >= 8 else []
    losses = train_main([
        "--arch", "qwen3-moe-tiny", "--steps", str(args.steps or 5),
        "--batch", "4", "--seq", "32", "--lr", "1e-3", "--moe-skew", "6.0",
    ] + mesh)
    assert all(math.isfinite(l) for l in losses), losses
    print(f"moe-train-smoke: {len(losses)} steps, all losses finite")
elif args.tiny:
    train_main([
        "--arch", "qwen3-0.6b", "--reduced", "--steps", str(args.steps or 30),
        "--batch", "4", "--seq", "32", "--lr", "5e-3",
        "--ckpt-dir", "/tmp/repro_train_tiny",
    ])
else:
    # ~20M params: qwen3 family at 1/4 width, full depth-ish
    import jax.numpy as jnp
    from dataclasses import replace

    from repro.configs.base import ARCHS
    from repro.launch import train as t

    cfg = replace(
        ARCHS["qwen3-0.6b"],
        name="qwen3-20m",
        n_layers=8, d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
        d_ff=1024, vocab_size=8192, kv_chunk=128,
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )
    ARCHS["qwen3-20m"] = cfg  # register for the driver
    t.main([
        "--arch", "qwen3-20m", "--steps", str(args.steps or 150),
        "--batch", "8", "--seq", "128", "--lr", "3e-3", "--microbatch", "2",
        "--ckpt-dir", "/tmp/repro_train_lm", "--state-dtype", "int8",
    ])
