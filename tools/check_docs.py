"""Docs gate: doctest docs/*.md + API docstrings, verify intra-repo links.

Three checks, any failure exits non-zero:

1. every fenced code block in ``docs/*.md`` that contains ``>>>`` lines runs
   as a doctest (shared namespace per file, so later blocks may use earlier
   imports);
2. every public export of ``repro``, ``repro.engine``, and ``repro.exchange``
   has a docstring with at least one executable ``>>>`` example, and all
   those examples pass;
3. every relative markdown link in ``docs/*.md`` and ``README.md`` resolves
   to a real file in the repo.

Run from the repo root:  PYTHONPATH=src python tools/check_docs.py
"""
from __future__ import annotations

import doctest
import os
import re
import sys

# the cluster_sort_kv doctest needs a multi-device mesh; force host devices
# before jax initializes (no-op on real multi-device hardware)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

FENCE_RE = re.compile(r"^```")
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def iter_doc_files():
    docs = sorted(
        os.path.join(REPO, "docs", f)
        for f in os.listdir(os.path.join(REPO, "docs"))
        if f.endswith(".md")
    )
    return docs + [os.path.join(REPO, "README.md")]


def extract_doctest_blocks(path: str):
    """Yield (first_line_no, text) for fenced blocks containing >>> lines."""
    lines = open(path).read().splitlines()
    block, start, in_fence = [], 0, False
    for i, line in enumerate(lines, 1):
        if FENCE_RE.match(line.strip()):
            if in_fence:
                text = "\n".join(block)
                if ">>>" in text:
                    yield start, text
                block, in_fence = [], False
            else:
                in_fence, start = True, i
        elif in_fence:
            block.append(line)


def check_markdown_doctests() -> int:
    failures = 0
    runner = doctest.DocTestRunner(optionflags=doctest.NORMALIZE_WHITESPACE)
    parser = doctest.DocTestParser()
    for path in iter_doc_files():
        if os.path.basename(path) == "README.md":
            continue  # README snippets are illustrative; docs/ ones must run
        globs: dict = {}
        for lineno, text in extract_doctest_blocks(path):
            rel = os.path.relpath(path, REPO)
            test = parser.get_doctest(text, globs, f"{rel}:{lineno}", rel, lineno)
            result = runner.run(test)
            if result.failed:
                print(f"FAIL doctest block at {rel}:{lineno}")
                failures += result.failed
    return failures


def check_api_docstrings() -> int:
    import repro
    import repro.engine
    import repro.exchange

    failures = 0
    runner = doctest.DocTestRunner(optionflags=doctest.NORMALIZE_WHITESPACE)
    finder = doctest.DocTestFinder(recurse=False)
    for mod in (repro, repro.engine, repro.exchange):
        for name in mod.__all__:
            obj = getattr(mod, name)
            doc = getattr(obj, "__doc__", None)
            if not doc or ">>>" not in doc:
                print(f"FAIL {mod.__name__}.{name}: docstring missing a >>> example")
                failures += 1
                continue
            for test in finder.find(obj, name=f"{mod.__name__}.{name}"):
                result = runner.run(test)
                if result.failed:
                    print(f"FAIL doctest: {mod.__name__}.{name}")
                    failures += result.failed
    return failures


def check_links() -> int:
    failures = 0
    for path in iter_doc_files():
        base = os.path.dirname(path)
        for target in LINK_RE.findall(open(path).read()):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            target = target.split("#")[0]
            if not target:
                continue  # pure in-page anchor
            if not os.path.exists(os.path.normpath(os.path.join(base, target))):
                print(f"FAIL broken link in {os.path.relpath(path, REPO)}: {target}")
                failures += 1
    return failures


def main() -> int:
    failures = check_links()
    failures += check_markdown_doctests()
    failures += check_api_docstrings()
    if failures:
        print(f"\n{failures} docs check(s) failed")
        return 1
    print("docs checks passed: links, markdown doctests, API docstring examples")
    return 0


if __name__ == "__main__":
    sys.exit(main())
