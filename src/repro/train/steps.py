"""Training and serving step functions (the things the dry-run lowers).

* ``train_step``: CE loss (vocab-chunked so (tokens, V) logits never
  materialize — mandatory at 256k vocab), optional MoE aux loss, grads,
  AdamW update, optional microbatch gradient accumulation via lax.scan.
* ``prefill_step``: full-sequence pass that fills the KV/SSM caches and
  returns last-position logits only (a (1M, 256k) fp32 logits tensor would be
  ~1 PB — serving returns what serving needs).
* ``serve_decode_step``: one token through the stack with caches.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import rmsnorm
from repro.models.transformer import (
    ModelConfig,
    ShardCtx,
    _apply_block,
    decode_step as model_decode_step,
    forward,
    init_cache,
)
from repro.optim.adamw import OptConfig, apply_updates


# ------------------------------------------------------------ chunked CE ---
def chunked_ce_loss(
    x: jax.Array,            # (B, S, D) final hidden states (pre-unembed)
    p_embed: dict,           # {"table": (V, D)} tied embedding (vocab-parallel)
    labels: jax.Array,       # (B, S) int32; -1 = masked
    cfg: ModelConfig,
    ctx: ShardCtx,
    *,
    chunk: int = 512,
) -> jax.Array:
    """Mean CE, scanning vocab projection over *sequence* chunks.

    Chunking must follow the replicated (sequence) axis, not the global token
    count: a scan over global token chunks serializes cross-device data and
    all-reduces every (c, V) logits chunk — 126 GiB/step on qwen3-train_4k
    (refuted hypothesis H-loss, EXPERIMENTS §Perf). Here batch stays sharded;
    logits are V-sharded over the EP axis (table is vocab-parallel) and the
    gold logit is a second vocab-parallel lookup: dot(x, table[label]) —
    no full-logits collective anywhere.
    """
    from repro.models.transformer import embed_tokens

    B, S, D = x.shape
    c = min(chunk, S)
    while S % c:
        c -= 1
    nc = S // c
    xc = jnp.moveaxis(x.reshape(B, nc, c, D), 1, 0)          # (nc, B, c, D)
    yc = jnp.moveaxis(labels.reshape(B, nc, c), 1, 0)        # (nc, B, c)
    table = p_embed["table"]

    v_pad = table.shape[0]

    def body(acc, inp):
        xb, yb = inp                                          # (B,c,D), (B,c)
        logits = jnp.einsum(
            "bcd,vd->bcv", xb, table.astype(xb.dtype),
            preferred_element_type=jnp.float32,
        )
        if v_pad != cfg.vocab_size:  # EP-padding rows never win
            logits = jnp.where(jnp.arange(v_pad) < cfg.vocab_size, logits, -jnp.inf)
        lz = jax.nn.logsumexp(logits, axis=-1)                # (B, c)
        gold_emb = embed_tokens(p_embed, jnp.maximum(yb, 0), cfg, ctx)
        gold = jnp.sum(xb.astype(jnp.float32) * gold_emb.astype(jnp.float32), -1)
        valid = yb >= 0
        loss = jnp.where(valid, lz - gold, 0.0)
        return (acc[0] + loss.sum(), acc[1] + valid.sum()), None

    body = jax.checkpoint(body)
    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros(()), jnp.zeros((), jnp.int32)), (xc, yc))
    return tot / jnp.maximum(cnt, 1)


def _hidden_states(
    params, cfg: ModelConfig, tokens, frontend_embeds, ctx, remat,
    moe_capacity=None,
):
    """Run the stack up to final norm, returning hidden states + stats.

    The stats dict always carries ``moe_dropped`` (tokens lost to capacity
    overflow, summed over layers) and ``moe_peak`` (hottest per-(sender,
    expert) token count, maxed over layers) alongside ``moe_aux`` /
    ``moe_overflow`` — the telemetry the between-step capacity learner and
    ``AnomalyMonitor`` read.  ``moe_capacity`` (static) overrides every MoE
    layer's per-(sender, expert) capacity: the train driver threads the
    learned value through here, so a capacity bump recompiles the step once.
    """
    from repro.models.transformer import _apply_block, embed_tokens

    x = embed_tokens(params["embed"], tokens, cfg, ctx)
    if frontend_embeds is not None:
        F = frontend_embeds.shape[1]
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x[:, F:]], axis=1)
    aux0 = jnp.zeros((), jnp.float32)
    ovf0 = jnp.asarray(False)
    drop0 = jnp.zeros((), jnp.int32)
    peak0 = jnp.zeros((), jnp.int32)

    def group_body(carry, gp):
        x, aux, ovf, drp, pk = carry
        x = ctx.constrain_batch(x)  # anchor the scan carry's batch sharding
        stats = {
            "moe_aux": aux, "moe_overflow": ovf,
            "moe_dropped": drp, "moe_peak": pk,
        }
        for i, (kind, ffn) in enumerate(zip(cfg.pattern, cfg.ffn_pattern)):
            x, stats = _apply_block(
                gp[f"pos{i}"], cfg, kind, ffn, x, ctx, stats,
                moe_capacity=moe_capacity, moe_stats=True,
            )
        return (
            x, stats["moe_aux"], stats["moe_overflow"],
            jnp.asarray(stats["moe_dropped"], jnp.int32),
            jnp.asarray(stats["moe_peak"], jnp.int32),
        ), None

    body = group_body
    if remat:
        policy = (
            jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
            if cfg.remat_policy == "dots"
            else None  # "none": recompute everything per group (the giants)
        )
        body = jax.checkpoint(group_body, policy=policy)
    (x, aux, ovf, drp, pk), _ = jax.lax.scan(
        body, (x, aux0, ovf0, drop0, peak0), params["blocks"]
    )
    x = rmsnorm(params["final_norm"], x)
    return x, {
        "moe_aux": aux / max(cfg.n_layers, 1),
        "moe_overflow": ovf,
        "moe_dropped": drp,
        "moe_peak": pk,
    }


def loss_fn(
    params,
    cfg: ModelConfig,
    batch: dict,
    *,
    ctx: ShardCtx = ShardCtx(),
    aux_weight: float = 0.01,
    loss_chunk: int = 512,
    remat: bool = True,
    moe_capacity: Optional[int] = None,
):
    x, stats = _hidden_states(
        params, cfg, batch["tokens"], batch.get("frontend_embeds"), ctx, remat,
        moe_capacity,
    )
    ce = chunked_ce_loss(x, params["embed"], batch["labels"], cfg, ctx, chunk=loss_chunk)
    loss = ce + aux_weight * stats["moe_aux"]
    return loss, {"ce": ce, **stats}


def train_step(
    params,
    opt_state,
    batch: dict,
    *,
    cfg: ModelConfig,
    opt_cfg: OptConfig,
    ctx: ShardCtx = ShardCtx(),
    n_microbatch: int = 1,
    loss_chunk: int = 512,
    remat: bool = True,
    moe_capacity: Optional[int] = None,
):
    """One optimizer step (optionally accumulating over microbatches).

    ``moe_capacity`` (static) pins every MoE layer's per-(sender, expert)
    capacity — the train driver's capacity controller passes the learned
    value so a bump recompiles the step exactly once, like the serving path.
    The returned metrics carry ``moe_dropped``/``moe_peak`` (summed / maxed
    over microbatches) for the controller to fold back into the planner.
    """

    def grads_of(b):
        (loss, stats), grads = jax.value_and_grad(
            lambda p: loss_fn(
                p, cfg, b, ctx=ctx, loss_chunk=loss_chunk, remat=remat,
                moe_capacity=moe_capacity,
            ),
            has_aux=True,
        )(params)
        return loss, stats, grads

    if n_microbatch == 1:
        loss, stats, grads = grads_of(batch)
    else:
        def split(leaf):
            B = leaf.shape[0]
            return leaf.reshape(n_microbatch, B // n_microbatch, *leaf.shape[1:])

        micro = jax.tree.map(split, batch)

        def acc_body(carry, mb):
            loss_a, grads_a = carry
            loss, stats, grads = grads_of(mb)
            return (
                loss_a + loss / n_microbatch,
                jax.tree.map(lambda a, g: a + g / n_microbatch, grads_a, grads),
            ), stats

        # (p*0) not zeros(): a bare-constant accumulator has no sharding and
        # unifies the scan carry to replicated — a full f32 param copy per
        # device (108 GiB on jamba; refuted hypothesis H-acc, EXPERIMENTS §Perf)
        zero_g = jax.tree.map(lambda p: (p * 0).astype(jnp.float32), params)
        (loss, grads), stats_seq = jax.lax.scan(acc_body, (jnp.zeros(()), zero_g), micro)
        # drops accumulate across microbatches, peak is the step's hottest
        # count; everything else keeps last-microbatch semantics
        reduce = {"moe_dropped": jnp.sum, "moe_peak": jnp.max}
        stats = {
            k: reduce[k](s) if k in reduce else s[-1] for k, s in stats_seq.items()
        }

    new_params, new_opt, metrics = apply_updates(params, grads, opt_state, opt_cfg)
    metrics = {**metrics, "loss": loss, **{k: v for k, v in stats.items()}}
    return new_params, new_opt, metrics


# ---------------------------------------------------------------- serving ---
def prefill_step(
    params,
    cfg: ModelConfig,
    tokens: jax.Array,                      # (B, S)
    *,
    ctx: ShardCtx = ShardCtx(),
    frontend_embeds: Optional[jax.Array] = None,
    cache_len: Optional[int] = None,
):
    """Fill caches for the whole prompt; return (last_logits (B,V), cache)."""
    from repro.models.attention import KVCache, attention_train, init_kv_cache
    from repro.models.layers import embed, unembed
    from repro.models.mamba2 import mamba_train
    from repro.models.transformer import _apply_ffn, embed_tokens

    B, S = tokens.shape
    cache_len = cache_len or S
    x = embed_tokens(params["embed"], tokens, cfg, ctx)
    if frontend_embeds is not None:
        F = frontend_embeds.shape[1]
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x[:, F:]], axis=1)

    pin = ctx.constrain_spec if ctx.mesh is not None else None
    # pin heads only when H doesn't divide the TP axis (see _apply_block)
    attn_pin = (
        pin if (pin and cfg.n_heads % ctx.mesh.shape[ctx.ep_axis]) else None
    )

    def group_body(x, gp):
        new_cache = {}
        for i, (kind, ffn) in enumerate(zip(cfg.pattern, cfg.ffn_pattern)):
            p = gp[f"pos{i}"]
            h = rmsnorm(p["norm1"], x)
            if kind.startswith("attn"):
                acfg = cfg.attn_cfg(kind)
                from repro.models.attention import _pin_heads, _project_qkv

                positions = jnp.broadcast_to(jnp.arange(S), (B, S))
                q, k, v = _project_qkv(p["attn"], acfg, h, positions)
                q, k, v = _pin_heads(q, k, v, attn_pin)
                if acfg.sliding_window and S > acfg.sliding_window:
                    from repro.models.attention import _blocked_local

                    out = _blocked_local(q, k, v, acfg)
                    w = acfg.sliding_window
                    kc, vc = k[:, -w:], v[:, -w:]  # ring buffer, filled in order
                    # ring slot of position S-w+j is (S-w+j) % w == (S+j) % w
                    roll = (-(S % w)) % w
                    kc = jnp.roll(kc, -roll, axis=1)
                    vc = jnp.roll(vc, -roll, axis=1)
                else:
                    from repro.models.attention import _flash_causal

                    out = _flash_causal(q, k, v, acfg, constrain=attn_pin)
                    pad = cache_len - S
                    kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                    vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
                from repro.models.layers import linear

                x = x + linear(p["attn"]["wo"], out.reshape(B, S, -1))
                new_cache[f"pos{i}"] = KVCache(
                    kc.astype(cfg.compute_dtype),
                    vc.astype(cfg.compute_dtype),
                    jnp.asarray(S, jnp.int32),
                )
            else:
                mcfg = cfg.mamba_cfg()
                from repro.models.mamba2 import MambaCache, _causal_conv, _split_proj, _ssd_chunked
                from repro.models.layers import linear

                z, xbc, dt = _split_proj(mcfg, linear(p["mamba"]["in_proj"], h))
                xbc_conv = _causal_conv(p["mamba"], mcfg, xbc)
                nh, hp, ds, ng = mcfg.n_heads, mcfg.head_dim, mcfg.d_state, mcfg.n_groups
                xs = xbc_conv[..., : mcfg.d_inner].reshape(B, S, nh, hp)
                B_ = xbc_conv[..., mcfg.d_inner : mcfg.d_inner + ng * ds].reshape(B, S, ng, ds)
                C_ = xbc_conv[..., mcfg.d_inner + ng * ds :].reshape(B, S, ng, ds)
                dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["mamba"]["dt_bias"])
                A = -jnp.exp(p["mamba"]["A_log"])
                y, h_last = _ssd_chunked(mcfg, xs, dtv, B_, C_, A)
                y = y + p["mamba"]["D_skip"][:, None] * xs.astype(jnp.float32)
                y = y.reshape(B, S, mcfg.d_inner).astype(x.dtype)
                y = rmsnorm(p["mamba"]["norm"], y * jax.nn.silu(z))
                x = x + linear(p["mamba"]["out_proj"], y)
                new_cache[f"pos{i}"] = MambaCache(
                    conv=xbc[:, S - (mcfg.conv_kernel - 1) :, :].astype(cfg.compute_dtype),
                    ssm=h_last,
                )
            if ffn is not None:
                x, _ = _apply_ffn(p, cfg, x, ctx, {})
        return x, new_cache

    x, cache = jax.lax.scan(group_body, x, params["blocks"])
    x_last = rmsnorm(params["final_norm"], x[:, -1:])
    logits = unembed(params["embed"], x_last, cfg.vocab_size)[:, 0]
    return logits, cache


def serve_decode_step(params, cfg: ModelConfig, tokens, cache, *, ctx=ShardCtx()):
    """One decode token for the whole batch; returns (logits (B,1,V), cache)."""
    return model_decode_step(params, cfg, tokens, cache, ctx=ctx)
