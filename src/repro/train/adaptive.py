"""Between-step MoE capacity control: the training half of the adaptive loop.

Serving learns expert capacity inside the call (``moe_apply_adaptive`` /
``moe_apply_local_adaptive`` retry with doubled capacity); a jitted train
step cannot retry — recomputing the batch would change optimizer state — so
training closes the same loop *between* steps instead:

1. before a step, ``MoECapacityController.capacity`` converts the planner's
   learned factor for this (n_experts, top_k, token bucket, mesh) cell into
   a static per-(sender, expert) capacity (``train_step(moe_capacity=...)``);
2. the jitted step threads ``moe_dropped``/``moe_peak`` out of the stack
   (``repro.train.steps``);
3. after the step, ``observe`` folds them into the planner as an
   ``ExchangeObservation`` — the same telemetry schema serving reports — so
   the learned factor jumps above the observed peak and the *next* step's
   capacity recompiles once at the provisioned size.

Factors persist through the fcntl-locked plan cache, so capacity learned in
training warms serving and vice versa (docs/exchange.md, docs/plan-cache.md).
"""
from __future__ import annotations

import math
from typing import Optional

import jax.numpy as jnp

from repro.core.bitonic import next_pow2
from repro.exchange import ExchangeObservation, expert_capacity
from repro.models.moe import MoEConfig, moe_plan_key


class MoECapacityController:
    """Host-side capacity policy for one (model, token shape, mesh) cell.

    ``tokens`` is the *global* token count one forward pass dispatches (one
    microbatch: ``batch * seq / n_microbatch``); the per-sender slice that
    sizes slabs is derived from the mesh in ``ctx`` (every mesh axis shards
    the token flatten — ``moe_shard_specs``'s convention — so a 2x4 mesh
    splits 512 tokens into 64-token senders; ``ctx.mesh is None`` means the
    replicated single-sender path).

    The controller is deliberately dumb: all learning lives in the planner's
    ``CapacityLearner`` (jump on pressure, decay toward the config default),
    all persistence in the plan cache. This class only converts between the
    step function's static-capacity world and the planner's factor world.
    """

    def __init__(self, cfg: MoEConfig, tokens: int, *, ctx, planner,
                 dtype=jnp.float32):
        self.cfg = cfg
        self.tokens = int(tokens)
        self.planner = planner
        n_dev = 1
        if ctx.mesh is not None:
            for a in ctx.axes:
                n_dev *= ctx.mesh.shape[a]
        if self.tokens % n_dev:
            raise ValueError(
                f"tokens {self.tokens} must divide the {n_dev}-device mesh"
            )
        self.t_loc = self.tokens // n_dev       # per-sender token slice
        self.m = self.t_loc * cfg.top_k         # per-sender assignments
        self.key = moe_plan_key(self.tokens, cfg, dtype, ctx.mesh)

    @property
    def factor(self) -> float:
        """The cell's current learned capacity factor (config default until
        telemetry taught the planner otherwise)."""
        return self.planner.capacity_factor_for(
            self.key, default=self.cfg.capacity_factor
        )

    @property
    def capacity(self) -> int:
        """Per-(sender, expert) token capacity for the next step — static,
        so the driver keys its compiled step functions on it and a learned
        bump costs exactly one recompile.

        The raw factor-derived capacity is **bucketed to the next power of
        two** (the same pow2 bucketing token counts use), clamped to ``m``
        — the per-sender assignment count, beyond which capacity is
        loss-free by construction.  Without the bucket, a gradually
        decaying learned factor would shift the raw capacity by one or two
        tokens step after step, and since the driver keys compiled step
        functions on capacity, every shift would be a fresh lowering; with
        it, the factor must halve the raw capacity before a new executable
        is built.
        """
        raw = expert_capacity(
            self.t_loc, self.cfg.top_k, self.cfg.n_experts, self.factor
        )
        return min(next_pow2(max(raw, 1)), max(self.m, 1))

    def observe(self, metrics: dict, *, capacity: Optional[int] = None) -> None:
        """Fold one completed step's ``moe_dropped``/``moe_peak`` metrics
        into the planner (and its telemetry ledger, which AnomalyMonitor
        may be watching).  ``capacity`` is the value the step actually ran
        at; defaults to the current one for callers that don't cache it.

        A training step never retries, so every dropped token reached the
        served (trained-on) output: ``dropped`` is reported as real loss,
        never as averted.
        """
        cap = int(self.capacity if capacity is None else capacity)
        # peak is maxed over layers and microbatches; dropped sums layers
        # and microbatches of one step. With L MoE layers a steady skew
        # reports ~L * per-layer drops — fine: the learner reads peak, and
        # dropped>0 only gates the overflow flag / anomaly counter.
        dropped = int(metrics.get("moe_dropped", 0))
        peak = int(metrics.get("moe_peak", 0))
        obs = ExchangeObservation(
            m=self.m,
            part_buckets=max(self.cfg.n_experts, 1),
            capacity=cap,
            peak=peak,
            overflowed=bool(dropped > 0 or peak > cap),
            retries=0,
            recompiles=0,
            dropped=dropped,
        )
        self.planner.observe_exchange(
            self.key, obs, default=self.cfg.capacity_factor
        )


def parse_mesh_spec(spec: str):
    """``"data=2,model=4"`` -> a ``jax.Mesh`` plus its axis-name tuple.

    The train driver's --mesh flag: axis order is the spec's order (tokens
    shard over every axis, experts over the ``model`` axis by ShardCtx
    convention).  Raises ValueError when the requested devices exceed what
    the runtime has.

    >>> mesh, axes = parse_mesh_spec("data=1,model=1")
    >>> axes
    ('data', 'model')
    >>> dict(mesh.shape)
    {'data': 1, 'model': 1}
    """
    import jax

    pairs = []
    for part in spec.split(","):
        name, _, size = part.partition("=")
        if not name or not size:
            raise ValueError(f"bad mesh spec {spec!r} (want axis=size,...)")
        pairs.append((name.strip(), int(size)))
    names = tuple(n for n, _ in pairs)
    sizes = tuple(s for _, s in pairs)
    need = math.prod(sizes)
    have = len(jax.devices())
    if need > have:
        raise ValueError(f"mesh {spec!r} needs {need} devices, have {have}")
    return jax.make_mesh(sizes, names), names
