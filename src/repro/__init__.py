"""repro — parallel-sort reproduction framework.

Import-time compat shims for jax API drift live here so every entry point
(src modules, test subprocess snippets, examples) sees one consistent API.

Public façade: ``repro.sort`` (the autotuned front door over the paper's four
models) and the ``repro.engine`` subpackage (plans, key–value sorting, the
batched serving service).  See ``docs/architecture.md`` for the layer map.
"""
import jax as _jax

if not hasattr(_jax, "shard_map"):
    # jax < 0.5 ships shard_map under experimental (with check_vma spelled
    # check_rep); newer jax promotes it to jax.shard_map.
    from jax.experimental.shard_map import shard_map as _shard_map

    def _shard_map_compat(f, **kwargs):
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        # the old checker has false positives (e.g. psum inside scan) that the
        # jax this codebase targets no longer flags — keep behaviour aligned
        kwargs.setdefault("check_rep", False)
        return _shard_map(f, **kwargs)

    _jax.shard_map = _shard_map_compat

if not hasattr(_jax.lax, "axis_size"):
    # psum of a constant folds to a Python int at trace time — the idiomatic
    # axis-size query before jax grew lax.axis_size.
    _jax.lax.axis_size = lambda axis_name: _jax.lax.psum(1, axis_name)

# the shims above must be installed before any repro module touches jax,
# so the façade import sits below them deliberately
from repro.core.api import sort  # noqa: E402

__all__ = ["sort"]
