"""Pure-jnp oracles for the Pallas bitonic sort kernels.

The kernels decompose the canonical n-element bitonic network into:

  phase 1   per-block sort, block b ascending iff b even          (kernel A)
  stage k   global substages j = k/2 .. block_n  (elementwise)    (jnp / kernel C)
            local substages  j = block_n/2 .. 1  (in-VMEM)        (kernel B)

Each oracle below is the bit-exact jnp reference of one kernel, plus
``full_sort_ref`` (= jnp.sort) for the end-to-end op.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.bitonic import _compare_exchange, _network  # shared network code


def block_sort_ref(x: jax.Array, block_n: int) -> jax.Array:
    """Kernel A oracle: sort aligned blocks, alternating asc/desc per block."""
    n = x.shape[-1]
    nb = n // block_n
    blocks = x.reshape(*x.shape[:-1], nb, block_n)
    asc, _, _ = _network(blocks, None, None, ascending=True)
    desc, _, _ = _network(blocks, None, None, ascending=False)
    even = (jnp.arange(nb) % 2 == 0)[:, None]
    return jnp.where(even, asc, desc).reshape(x.shape)


def block_merge_ref(x: jax.Array, block_n: int, k: int) -> jax.Array:
    """Kernel B oracle: all substages j = block_n/2 .. 1 of stage ``k``.

    Assumes substages j >= block_n of stage k have already been applied, so the
    comparator direction is uniform within each block: up iff (b*block_n & k)==0.
    """
    n = x.shape[-1]
    sub = block_n // 2
    while sub >= 1:
        j = sub
        g = n // (2 * j)
        blk_of_group = (jnp.arange(g) * 2 * j) // k
        dir_up = blk_of_group % 2 == 0
        x, _, _ = _compare_exchange(x, None, None, j, dir_up, ascending=True)
        sub //= 2
    return x


def global_stage_ref(x: jax.Array, j: int, k: int) -> jax.Array:
    """Kernel C oracle: one cross-block substage (partner distance j >= block_n)."""
    n = x.shape[-1]
    g = n // (2 * j)
    dir_up = ((jnp.arange(g) * 2 * j) // k) % 2 == 0
    x, _, _ = _compare_exchange(x, None, None, j, dir_up, ascending=True)
    return x


def full_sort_ref(x: jax.Array) -> jax.Array:
    """End-to-end oracle for the composed op."""
    return jnp.sort(x, axis=-1)
