"""Jitted composition of the Pallas bitonic kernels: full-array sort.

``pallas_sort(x)`` sorts the last axis of a 1-D array whose length is a
power-of-two multiple of ``block_n``:

  phase 1:  kernel A  (per-block alternating-direction sort)
  stages k = 2*block_n .. n:
     j = k/2 .. block_n   : cross-block elementwise compare-exchange (jnp)
     j = block_n/2 .. 1   : kernel B (one fused VMEM pass)

On CPU (this container) the kernels run in interpret mode; on TPU they compile
through Mosaic. ``interpret=None`` auto-detects.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .bitonic_sort import block_merge, block_sort, global_stage


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def _pallas_sort_impl(x, *, block_n: int, interpret: bool):
    n = x.shape[-1]
    x = block_sort(x, block_n, interpret=interpret)
    k = 2 * block_n
    while k <= n:
        j = k // 2
        while j >= block_n:
            x = global_stage(x, j, k)
            j //= 2
        x = block_merge(x, block_n, k, interpret=interpret)
        k *= 2
    return x


def pallas_sort(x: jax.Array, *, block_n: int = 1024, interpret=None) -> jax.Array:
    """Sort 1-D ``x`` (length = pow2 multiple of block_n) ascending."""
    if x.ndim != 1:
        raise ValueError("pallas_sort expects a 1-D array")
    n = x.shape[-1]
    if n % block_n or n & (n - 1):
        raise ValueError(f"n={n} must be a power-of-two multiple of block_n={block_n}")
    if n == block_n or n < block_n:
        block_n = min(block_n, n)
    if interpret is None:
        interpret = _auto_interpret()
    return _pallas_sort_impl(x, block_n=block_n, interpret=interpret)
