"""Jitted composition of the Pallas bitonic kernels: sort / argsort / kv-sort.

``pallas_sort(x)`` sorts the last axis of a 1-D array of *any* length >= 1:
the wrapper pads to the next power of two with +sentinel keys, runs the tiled
network, and slices the valid prefix back out.

  phase 1:  kernel A  (per-block alternating-direction sort)
  stages k = 2*block_n .. n:
     j = k/2 .. block_n   : cross-block elementwise compare-exchange (jnp)
     j = block_n/2 .. 1   : kernel B (one fused VMEM pass)

``pallas_argsort(x)`` runs the same network on (key, rank) pairs with a
lexicographic comparator (kernels' ``*_kv`` twins) — ranks never tie, so the
returned permutation is the *stable* one, matching
``np.argsort(kind='stable')``.  ``pallas_sort_kv(keys, values)`` gathers an
arbitrary values pytree by that permutation.

``block_n`` is the VMEM tile width and the kernels' main tuning knob: bigger
blocks fuse more substages per HBM round-trip but raise per-program VMEM
pressure.  It must be a power of two; it is clamped to the (padded) problem
size, so any ``block_n`` is safe for any input length.  ``engine.planner``
sweeps {256, 512, 1024} per size bucket and persists the winner in the plan
cache.

NaN caveat: like the pure-jnp bitonic network (and unlike XLA's sort), the
comparator is plain ``>``, so NaN float keys produce unspecified output —
reject or strip NaN at the boundary (``SortService`` does).

On CPU (this container) the kernels run in interpret mode; on TPU they compile
through Mosaic. ``interpret=None`` auto-detects.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.bitonic import next_pow2, sentinel_for

from .bitonic_sort import (
    block_merge,
    block_merge_kv,
    block_sort,
    block_sort_kv,
    global_stage,
    global_stage_kv,
)

__all__ = [
    "pallas_sort",
    "pallas_argsort",
    "pallas_sort_kv",
    "vmap_last_axis",
    "DEFAULT_BLOCK_N",
]

DEFAULT_BLOCK_N = 1024


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def vmap_last_axis(fn, x: jax.Array) -> jax.Array:
    """Apply a 1-D-in/1-D-out ``fn`` over the last axis of any-rank ``x``.

    The shared batching wrapper for the 1-D kernel entry points below
    (used by core.seqsort and engine.kv so the semantics live in one place).
    """
    if x.ndim == 1:
        return fn(x)
    *lead, n = x.shape
    return jax.vmap(fn)(x.reshape(-1, n)).reshape(*lead, n)


def _resolve_shape(n: int, block_n: int):
    """(padded length, effective block_n) for an arbitrary input length."""
    if block_n < 1 or block_n & (block_n - 1):
        raise ValueError(f"block_n={block_n} must be a power of two")
    np2 = next_pow2(max(n, 1))
    return np2, min(block_n, np2)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def _pallas_sort_impl(x, *, block_n: int, interpret: bool):
    n = x.shape[-1]
    x = block_sort(x, block_n, interpret=interpret)
    k = 2 * block_n
    while k <= n:
        j = k // 2
        while j >= block_n:
            x = global_stage(x, j, k)
            j //= 2
        x = block_merge(x, block_n, k, interpret=interpret)
        k *= 2
    return x


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def _pallas_argsort_impl(x, *, block_n: int, interpret: bool):
    n = x.shape[-1]
    r = jnp.arange(n, dtype=jnp.int32)
    x, r = block_sort_kv(x, r, block_n, interpret=interpret)
    k = 2 * block_n
    while k <= n:
        j = k // 2
        while j >= block_n:
            x, r = global_stage_kv(x, r, j, k)
            j //= 2
        x, r = block_merge_kv(x, r, block_n, k, interpret=interpret)
        k *= 2
    return x, r


def pallas_sort(x: jax.Array, *, block_n: int = DEFAULT_BLOCK_N, interpret=None) -> jax.Array:
    """Sort 1-D ``x`` (any length >= 1) ascending via the tiled Pallas network.

    Non-pow2 lengths are padded with +sentinel keys and sliced back; pad keys
    can only displace *equal* (sentinel-valued) real keys, so the prefix is
    always the correct sorted output.
    """
    if x.ndim != 1:
        raise ValueError("pallas_sort expects a 1-D array")
    n = x.shape[-1]
    if n < 1:
        raise ValueError("pallas_sort needs at least one element")
    np2, block_n = _resolve_shape(n, block_n)
    if interpret is None:
        interpret = _auto_interpret()
    if np2 != n:
        x = jnp.pad(x, (0, np2 - n), constant_values=sentinel_for(x.dtype, largest=True))
    out = _pallas_sort_impl(x, block_n=block_n, interpret=interpret)
    return out[:n] if np2 != n else out


def pallas_argsort(
    x: jax.Array, *, block_n: int = DEFAULT_BLOCK_N, interpret=None
) -> jax.Array:
    """Stable ascending argsort of 1-D ``x`` (any length >= 1).

    Matches ``np.argsort(kind='stable')``: the (key, rank) comparator in the
    kv kernels is a total order, and pad entries (sentinel key, rank >= n)
    sort after every real element — even real elements equal to the sentinel,
    whose ranks are < n — so the sliced prefix only holds valid indices.
    """
    if x.ndim != 1:
        raise ValueError("pallas_argsort expects a 1-D array")
    n = x.shape[-1]
    if n < 1:
        raise ValueError("pallas_argsort needs at least one element")
    np2, block_n = _resolve_shape(n, block_n)
    if interpret is None:
        interpret = _auto_interpret()
    if np2 != n:
        x = jnp.pad(x, (0, np2 - n), constant_values=sentinel_for(x.dtype, largest=True))
    _, perm = _pallas_argsort_impl(x, block_n=block_n, interpret=interpret)
    return perm[:n] if np2 != n else perm


def pallas_sort_kv(
    keys: jax.Array,
    values,
    *,
    block_n: int = DEFAULT_BLOCK_N,
    interpret=None,
):
    """Stable key-value sort: 1-D keys, values pytree of (n, ...) payloads.

    Sorts the keys with the kv network and gathers every values leaf by the
    induced (stable) permutation. Returns ``(sorted_keys, permuted_values)``.
    """
    perm = pallas_argsort(keys, block_n=block_n, interpret=interpret)
    return keys[perm], jax.tree.map(lambda v: v[perm], values)
