"""Pallas TPU kernels for the bitonic sort network (VMEM-tiled).

Decomposition (see ref.py): the canonical n-element network is split so that
every O(log^2 block_n) "local" substage runs inside VMEM, and only the
O(log^2 (n/block_n)) cross-block substages touch HBM between kernel launches.
For block_n = 8192 fp32 that is a 32 KiB working set per program — well inside
the ~16 MiB VMEM budget even with double buffering, and every compare-exchange
is a branch-free ``min``/``max`` on VREG lanes (VPU work; the MXU is idle by
design — sorting is a bandwidth problem).

Kernels:
  A  _block_sort_kernel   per-block full network, direction alternating by
                          block parity (grid = n/block_n programs)
  B  _block_merge_kernel  all substages j < block_n of one merge stage k in a
                          single VMEM pass (the perf-critical fusion: log2(bn)
                          HBM round-trips collapse into one)
  C  cross-block substages j >= block_n: one elementwise compare-exchange over
     block pairs, expressed at the jnp level (pure bandwidth, no reuse to
     exploit — XLA emits the optimal elementwise kernel for it).

Each kernel also has a ``*_kv`` twin that carries an int32 rank array through
the same network with a lexicographic (key, rank) comparator.  Ranks start as
iota, ranks never tie, so the comparator is a total order and the rank output
is the *stable* sorting permutation — that one permutation is what
``ops.pallas_argsort`` / ``ops.pallas_sort_kv`` gather arbitrary value
payloads with.  Carrying ranks doubles the VMEM working set per program
(still tiny: 2 * 4 B * block_n) and stays branch-free on VREG lanes.

TPU layout note: blocks are processed as (block_n,) vectors; the power-of-two
reshapes inside the network lower to lane shuffles/rolls on Mosaic. Any pow2
block_n works (the wrapper clamps it to the padded problem size, and the
planner sweeps 256/512/1024); multiples of 1024 keep every sub-reshape
lane-aligned and are the perf-preferred choice on real TPUs — autotune skips
any candidate whose lowering fails, so an unsupported tile on some Mosaic
version degrades to "not selected", never a crash. Validated element-exact
against ref.py in interpret mode (CPU) — the TPU is the target.

Comparator caveat (shared with the pure-jnp network in core/bitonic.py): the
compare-exchange uses ``>``, under which NaN compares false everywhere — NaN
keys make the network's output unspecified. Callers that must reject NaN do
so at the boundary (e.g. SortService); XLA's own sort is the NaN-safe path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ce_flat(x, j: int, dir_up_vec):
    """Compare-exchange at distance j on a flat (n,) array (in-kernel body)."""
    n = x.shape[-1]
    g = n // (2 * j)
    x2 = x.reshape(g, 2, j)
    a, b = x2[:, 0, :], x2[:, 1, :]
    swap = (a > b) == dir_up_vec[:, None]
    lo = jnp.where(swap, b, a)
    hi = jnp.where(swap, a, b)
    return jnp.stack([lo, hi], axis=1).reshape(n)


def _ce_flat_kv(x, r, j: int, dir_up_vec):
    """Compare-exchange carrying ranks: lexicographic (key, rank) comparator.

    Ranks are unique, so ``gt`` is a strict total order — equal keys order by
    original rank, which is exactly the stable permutation.
    """
    n = x.shape[-1]
    g = n // (2 * j)
    x2 = x.reshape(g, 2, j)
    r2 = r.reshape(g, 2, j)
    a, b = x2[:, 0, :], x2[:, 1, :]
    ra, rb = r2[:, 0, :], r2[:, 1, :]
    gt = (a > b) | ((a == b) & (ra > rb))
    swap = gt == dir_up_vec[:, None]
    lo = jnp.where(swap, b, a)
    hi = jnp.where(swap, a, b)
    rlo = jnp.where(swap, rb, ra)
    rhi = jnp.where(swap, ra, rb)
    return (
        jnp.stack([lo, hi], axis=1).reshape(n),
        jnp.stack([rlo, rhi], axis=1).reshape(n),
    )


def _block_sort_kernel(x_ref, o_ref, *, block_n: int):
    """Kernel A body: canonical network on one block; direction = block parity."""
    b = pl.program_id(0)
    asc = (b % 2) == 0  # traced bool; fold into comparator via XOR
    x = x_ref[...]
    log_n = block_n.bit_length() - 1
    for stage in range(1, log_n + 1):
        k = 1 << stage
        for sub in range(stage - 1, -1, -1):
            j = 1 << sub
            g = block_n // (2 * j)
            blk = (jnp.arange(g) * 2 * j) // k
            dir_up = (blk % 2 == 0) == asc
            x = _ce_flat(x, j, dir_up)
    o_ref[...] = x


def _block_sort_kv_kernel(x_ref, r_ref, ox_ref, or_ref, *, block_n: int):
    """Kernel A (kv twin): (key, rank) network on one block, parity direction."""
    b = pl.program_id(0)
    asc = (b % 2) == 0
    x = x_ref[...]
    r = r_ref[...]
    log_n = block_n.bit_length() - 1
    for stage in range(1, log_n + 1):
        k = 1 << stage
        for sub in range(stage - 1, -1, -1):
            j = 1 << sub
            g = block_n // (2 * j)
            blk = (jnp.arange(g) * 2 * j) // k
            dir_up = (blk % 2 == 0) == asc
            x, r = _ce_flat_kv(x, r, j, dir_up)
    ox_ref[...] = x
    or_ref[...] = r


def _block_merge_kernel(x_ref, o_ref, *, block_n: int, k: int):
    """Kernel B body: substages j = block_n/2 .. 1 of stage k, fused in VMEM.

    Stage k > block_n implies the comparator direction is uniform inside the
    block: up iff (block_start & k) == 0.
    """
    b = pl.program_id(0)
    up = ((b * block_n) & k) == 0
    x = x_ref[...]
    sub = block_n // 2
    while sub >= 1:
        j = sub
        g = block_n // (2 * j)
        dir_up = jnp.full((g,), True) == up
        x = _ce_flat(x, j, dir_up)
        sub //= 2
    o_ref[...] = x


def _block_merge_kv_kernel(x_ref, r_ref, ox_ref, or_ref, *, block_n: int, k: int):
    """Kernel B (kv twin): fused local substages of stage k with ranks."""
    b = pl.program_id(0)
    up = ((b * block_n) & k) == 0
    x = x_ref[...]
    r = r_ref[...]
    sub = block_n // 2
    while sub >= 1:
        j = sub
        g = block_n // (2 * j)
        dir_up = jnp.full((g,), True) == up
        x, r = _ce_flat_kv(x, r, j, dir_up)
        sub //= 2
    ox_ref[...] = x
    or_ref[...] = r


def block_sort(x: jax.Array, block_n: int, *, interpret: bool) -> jax.Array:
    """Launch kernel A over all aligned blocks of the last axis (1-D x)."""
    n = x.shape[-1]
    nb = n // block_n
    return pl.pallas_call(
        functools.partial(_block_sort_kernel, block_n=block_n),
        grid=(nb,),
        in_specs=[pl.BlockSpec((block_n,), lambda b: (b,))],
        out_specs=pl.BlockSpec((block_n,), lambda b: (b,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)


def block_merge(x: jax.Array, block_n: int, k: int, *, interpret: bool) -> jax.Array:
    """Launch kernel B (fused local substages of stage k) over all blocks."""
    n = x.shape[-1]
    nb = n // block_n
    return pl.pallas_call(
        functools.partial(_block_merge_kernel, block_n=block_n, k=k),
        grid=(nb,),
        in_specs=[pl.BlockSpec((block_n,), lambda b: (b,))],
        out_specs=pl.BlockSpec((block_n,), lambda b: (b,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)


def global_stage(x: jax.Array, j: int, k: int) -> jax.Array:
    """Cross-block substage (j >= block_n): elementwise compare-exchange.

    Pure-bandwidth step with zero data reuse; left at the jnp level where XLA
    already emits a single fused elementwise kernel (Design choice C above).
    """
    n = x.shape[-1]
    g = n // (2 * j)
    dir_up = ((jnp.arange(g) * 2 * j) // k) % 2 == 0
    x2 = x.reshape(g, 2, j)
    a, b = x2[:, 0, :], x2[:, 1, :]
    swap = (a > b) == dir_up[:, None]
    lo = jnp.where(swap, b, a)
    hi = jnp.where(swap, a, b)
    return jnp.stack([lo, hi], axis=1).reshape(n)


def _kv_specs(block_n: int):
    spec = pl.BlockSpec((block_n,), lambda b: (b,))
    return [spec, spec], [spec, spec]


def block_sort_kv(x: jax.Array, r: jax.Array, block_n: int, *, interpret: bool):
    """Launch kernel A (kv twin): returns (keys, ranks) per-block sorted."""
    nb = x.shape[-1] // block_n
    in_specs, out_specs = _kv_specs(block_n)
    return pl.pallas_call(
        functools.partial(_block_sort_kv_kernel, block_n=block_n),
        grid=(nb,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct(r.shape, r.dtype),
        ],
        interpret=interpret,
    )(x, r)


def block_merge_kv(x: jax.Array, r: jax.Array, block_n: int, k: int, *, interpret: bool):
    """Launch kernel B (kv twin) over all blocks."""
    nb = x.shape[-1] // block_n
    in_specs, out_specs = _kv_specs(block_n)
    return pl.pallas_call(
        functools.partial(_block_merge_kv_kernel, block_n=block_n, k=k),
        grid=(nb,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct(r.shape, r.dtype),
        ],
        interpret=interpret,
    )(x, r)


def global_stage_kv(x: jax.Array, r: jax.Array, j: int, k: int):
    """Cross-block substage (kv twin): (key, rank) compare-exchange at jnp level."""
    g = x.shape[-1] // (2 * j)
    dir_up = ((jnp.arange(g) * 2 * j) // k) % 2 == 0
    return _ce_flat_kv(x, r, j, dir_up)
