"""Pallas TPU kernels for the bitonic sort network (VMEM-tiled).

Decomposition (see ref.py): the canonical n-element network is split so that
every O(log^2 block_n) "local" substage runs inside VMEM, and only the
O(log^2 (n/block_n)) cross-block substages touch HBM between kernel launches.
For block_n = 8192 fp32 that is a 32 KiB working set per program — well inside
the ~16 MiB VMEM budget even with double buffering, and every compare-exchange
is a branch-free ``min``/``max`` on VREG lanes (VPU work; the MXU is idle by
design — sorting is a bandwidth problem).

Kernels:
  A  _block_sort_kernel   per-block full network, direction alternating by
                          block parity (grid = n/block_n programs)
  B  _block_merge_kernel  all substages j < block_n of one merge stage k in a
                          single VMEM pass (the perf-critical fusion: log2(bn)
                          HBM round-trips collapse into one)
  C  cross-block substages j >= block_n: one elementwise compare-exchange over
     block pairs, expressed at the jnp level (pure bandwidth, no reuse to
     exploit — XLA emits the optimal elementwise kernel for it).

TPU layout note: blocks are processed as (block_n,) vectors; the power-of-two
reshapes inside the network lower to lane shuffles/rolls on Mosaic. Keep
block_n a multiple of 1024 so every sub-reshape stays lane-aligned. Validated
element-exact against ref.py in interpret mode (CPU) — the TPU is the target.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ce_flat(x, j: int, dir_up_vec):
    """Compare-exchange at distance j on a flat (n,) array (in-kernel body)."""
    n = x.shape[-1]
    g = n // (2 * j)
    x2 = x.reshape(g, 2, j)
    a, b = x2[:, 0, :], x2[:, 1, :]
    swap = (a > b) == dir_up_vec[:, None]
    lo = jnp.where(swap, b, a)
    hi = jnp.where(swap, a, b)
    return jnp.stack([lo, hi], axis=1).reshape(n)


def _block_sort_kernel(x_ref, o_ref, *, block_n: int):
    """Kernel A body: canonical network on one block; direction = block parity."""
    b = pl.program_id(0)
    asc = (b % 2) == 0  # traced bool; fold into comparator via XOR
    x = x_ref[...]
    log_n = block_n.bit_length() - 1
    for stage in range(1, log_n + 1):
        k = 1 << stage
        for sub in range(stage - 1, -1, -1):
            j = 1 << sub
            g = block_n // (2 * j)
            blk = (jnp.arange(g) * 2 * j) // k
            dir_up = (blk % 2 == 0) == asc
            x = _ce_flat(x, j, dir_up)
    o_ref[...] = x


def _block_merge_kernel(x_ref, o_ref, *, block_n: int, k: int):
    """Kernel B body: substages j = block_n/2 .. 1 of stage k, fused in VMEM.

    Stage k > block_n implies the comparator direction is uniform inside the
    block: up iff (block_start & k) == 0.
    """
    b = pl.program_id(0)
    up = ((b * block_n) & k) == 0
    x = x_ref[...]
    sub = block_n // 2
    while sub >= 1:
        j = sub
        g = block_n // (2 * j)
        dir_up = jnp.full((g,), True) == up
        x = _ce_flat(x, j, dir_up)
        sub //= 2
    o_ref[...] = x


def block_sort(x: jax.Array, block_n: int, *, interpret: bool) -> jax.Array:
    """Launch kernel A over all aligned blocks of the last axis (1-D x)."""
    n = x.shape[-1]
    nb = n // block_n
    return pl.pallas_call(
        functools.partial(_block_sort_kernel, block_n=block_n),
        grid=(nb,),
        in_specs=[pl.BlockSpec((block_n,), lambda b: (b,))],
        out_specs=pl.BlockSpec((block_n,), lambda b: (b,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)


def block_merge(x: jax.Array, block_n: int, k: int, *, interpret: bool) -> jax.Array:
    """Launch kernel B (fused local substages of stage k) over all blocks."""
    n = x.shape[-1]
    nb = n // block_n
    return pl.pallas_call(
        functools.partial(_block_merge_kernel, block_n=block_n, k=k),
        grid=(nb,),
        in_specs=[pl.BlockSpec((block_n,), lambda b: (b,))],
        out_specs=pl.BlockSpec((block_n,), lambda b: (b,)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)


def global_stage(x: jax.Array, j: int, k: int) -> jax.Array:
    """Cross-block substage (j >= block_n): elementwise compare-exchange.

    Pure-bandwidth step with zero data reuse; left at the jnp level where XLA
    already emits a single fused elementwise kernel (Design choice C above).
    """
    n = x.shape[-1]
    g = n // (2 * j)
    dir_up = ((jnp.arange(g) * 2 * j) // k) % 2 == 0
    x2 = x.reshape(g, 2, j)
    a, b = x2[:, 0, :], x2[:, 1, :]
    swap = (a > b) == dir_up[:, None]
    lo = jnp.where(swap, b, a)
    hi = jnp.where(swap, a, b)
    return jnp.stack([lo, hi], axis=1).reshape(n)
