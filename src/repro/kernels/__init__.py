# Custom-kernel layer. bitonic_sort/ is the Pallas VMEM-tiled bitonic
# network behind local_impl="pallas" (core/seqsort.py dispatches to it;
# engine/planner.py autotunes its block_n). Add new kernels only for
# compute hot-spots the paper itself optimizes.
