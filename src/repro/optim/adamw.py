"""AdamW with distributed-scale options.

* cosine schedule with linear warmup, global-norm clipping
* ``state_dtype='int8'``: block-wise (128) quantized m/v moments — this is what
  lets a 398B AdamW fit a 256-chip pod (DESIGN.md §5): 2B weights + 1B+1B
  moments + 1/128 scales ≈ 4.07 bytes/param vs 10.
* ``compress_grads``: int8 block-quantized gradient exchange with an
  error-feedback accumulator (1-bit-Adam-style residual correction). Under
  auto-sharded pjit the DP all-reduce is inserted by XLA, so the quantizer
  models the wire format (quantize -> dequantize around the sync point) and
  the residual keeps the update unbiased over steps; the roofline accounts
  collective bytes at int8 when enabled.

All state is a plain pytree of arrays -> checkpoints/shardings treat it like
params. Quantized moments are stored as {"q": int8 (nb, 128), "scale": f32
(nb,)}; the logical shape is recovered from the matching param leaf.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

BLOCK = 128


@dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "f32"        # "f32" | "int8"
    compress_grads: bool = False    # int8 gradient exchange w/ error feedback


def lr_at(cfg: OptConfig, step) -> jax.Array:
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.peak_lr * jnp.where(step < cfg.warmup_steps, warm, cos)


# --------------------------------------------------- rowwise quantization ---
# int8 moments are stored in the *param's own shape* with one absmax scale per
# last-axis row. A flat (n/128,128) block layout needs a reshape between the
# param sharding and the block sharding, which XLA can only satisfy by full
# replication (108 GiB/device on jamba — refuted hypothesis H-opt2,
# EXPERIMENTS §Perf). Row-wise keeps q sharded exactly like its param.


def quantize_blockwise(x: jax.Array) -> dict:
    """fp array -> {q:int8 (x.shape), scale:f32 (x.shape[:-1])} rowwise absmax."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1) / 127.0
    q = jnp.round(xf / jnp.maximum(scale[..., None], 1e-12)).astype(jnp.int8)
    return {"q": q, "scale": scale}


def dequantize_blockwise(qs: dict, like: jax.Array) -> jax.Array:
    return (qs["q"].astype(jnp.float32) * qs["scale"][..., None]).reshape(like.shape)


def _is_q(x) -> bool:
    return isinstance(x, dict) and set(x) == {"q", "scale"}


# ----------------------------------------------------------------- states ---
def init_opt_state(params, cfg: OptConfig):
    zeros = lambda p: jnp.zeros_like(p, jnp.float32)
    if cfg.state_dtype == "int8":
        qzero = lambda p: {
            "q": jnp.zeros(p.shape, jnp.int8),
            "scale": jnp.zeros(p.shape[:-1], jnp.float32),
        }
        m = jax.tree.map(qzero, params)
        v = jax.tree.map(qzero, params)
    else:
        m = jax.tree.map(zeros, params)
        v = jax.tree.map(zeros, params)
    state = {"m": m, "v": v, "count": jnp.zeros((), jnp.int32)}
    if cfg.compress_grads:
        state["err"] = jax.tree.map(zeros, params)
    return state


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def apply_updates(params, grads, state, cfg: OptConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))

    lr = lr_at(cfg, count)
    bc1 = 1 - cfg.b1 ** count.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v, err):
        g = g.astype(jnp.float32) * scale
        new_err = None
        if cfg.compress_grads:
            corrected = g + err
            qs = quantize_blockwise(corrected)
            g = dequantize_blockwise(qs, corrected)
            new_err = corrected - g
        if cfg.state_dtype == "int8":
            # m: linear absmax; v: stored in 4th-root domain — linear int8 on v
            # zeroes small entries inside a block and 1/sqrt(v) explodes
            # (refuted hypothesis H-opt1, EXPERIMENTS.md §Perf)
            m_f = dequantize_blockwise(m, p)
            v_f = dequantize_blockwise(v, p) ** 4
        else:
            m_f, v_f = m, v
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * g * g
        step = (m_f / bc1) / (jnp.sqrt(v_f / bc2) + cfg.eps)
        new_p = p.astype(jnp.float32) - lr * (step + cfg.weight_decay * p.astype(jnp.float32))
        if cfg.state_dtype == "int8":
            m_f = quantize_blockwise(m_f)
            v_f = quantize_blockwise(v_f ** 0.25)
        return new_p.astype(p.dtype), m_f, v_f, new_err

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.flatten(state["m"], is_leaf=_is_q)[0]
    flat_v = jax.tree.flatten(state["v"], is_leaf=_is_q)[0]
    flat_e = (
        jax.tree.leaves(state["err"]) if cfg.compress_grads else [None] * len(flat_p)
    )
    out = [upd(*t) for t in zip(flat_p, flat_g, flat_m, flat_v, flat_e)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    mdef = jax.tree.structure(state["m"], is_leaf=_is_q)
    new_m = jax.tree.unflatten(mdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(mdef, [o[2] for o in out])

    new_state = {"m": new_m, "v": new_v, "count": count}
    if cfg.compress_grads:
        new_state["err"] = jax.tree.unflatten(treedef, [o[3] for o in out])
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
