"""Partition-mode policy: radix vs sample — skew-proof bucketing for the wire.

The paper's model D assigns every key a destination from its most
significant digit — a **radix** partition: fast, stateless, and wrong for
skewed key distributions, where a hot digit overloads one bucket and the
fixed-capacity slabs overflow.  The classic remedy is samplesort: each
shard contributes a strided sample of its sorted keys, the gathered sample
is sorted, and its quantiles become splitters — a **sample** partition
whose buckets are balanced by construction, whatever the distribution.

This module is the single home of that two-valued policy:

* ``PARTITION_MODES`` / ``partition_of`` — every partitioner mode name in
  the codebase (``decimal``, ``range``, ``radix``, ``splitters``,
  ``sample``) classified into its family, the value ``SortPlan.partition``
  persists and the ``CapacityLearner`` promotes on.
* ``radix_bucket_ids`` — the auto-ranged radix partition: equal-width
  buckets over the collectively observed ``[min, max]`` key range, so radix
  mode needs no static ``lo``/``hi`` hints and the autotuner can sweep it.
* ``sample_partition_ids`` — the upgraded sample partition over composite
  ``(key, id)`` splitters: ties are split by a per-element id, so even
  all-equal or duplicate-heavy distributions divide into near-perfectly
  balanced buckets (a plain key splitter sends an entire tie run to one
  bucket).  ``stable=True`` uses arrival-order ids, preserving the slab
  layout's stability guarantee for key-value sorts.
* ``choose_splitters`` / ``splitter_bucket`` / ``splitters_from_sample`` —
  the plain key-splitter primitives (``core/radix.py``'s ``splitters``
  mode, re-exported there for back-compat) plus the host-side derivation
  helper the property tests pin down.

Everything is shard_map-friendly: pure jnp on local shards, one small
``all_gather`` for the sample (negligible next to the data exchange).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "PARTITION_MODES",
    "DEFAULT_OVERSAMPLE",
    "partition_of",
    "radix_bucket_ids",
    "sample_partition_ids",
    "choose_splitters",
    "splitter_bucket",
    "splitters_from_sample",
]

# the two partition families the planner persists and the learner promotes
# between; every concrete partitioner mode belongs to exactly one of them
PARTITION_MODES = ("radix", "sample")

_FAMILY = {
    "decimal": "radix",     # the paper's MSD decimal digit (static)
    "range": "radix",       # equal-width over a static [lo, hi) hint
    "radix": "radix",       # equal-width over the collective [min, max]
    "splitters": "sample",  # plain key-quantile splitters
    "sample": "sample",     # composite (key, id) splitters
}

# sample size per shard = oversample * n_buckets; 16 keeps the splitter
# rank error well under half a mean bucket at the sizes the bench sweeps
DEFAULT_OVERSAMPLE = 16


def partition_of(mode: str) -> str:
    """Classify a partitioner mode name into its partition family.

    The family — ``'radix'`` or ``'sample'`` — is what ``SortPlan.partition``
    persists, what exchange telemetry tags observations with, and what the
    ``CapacityLearner``'s skew-promotion policy reasons about.

    >>> [partition_of(m) for m in ("decimal", "range", "radix")]
    ['radix', 'radix', 'radix']
    >>> [partition_of(m) for m in ("splitters", "sample")]
    ['sample', 'sample']
    >>> partition_of("quantum")
    Traceback (most recent call last):
        ...
    ValueError: unknown partitioner mode 'quantum'
    """
    try:
        return _FAMILY[mode]
    except KeyError:
        raise ValueError(f"unknown partitioner mode {mode!r}") from None


def radix_bucket_ids(
    keys: jax.Array, n_buckets: int, axis_name: str
) -> jax.Array:
    """Auto-ranged radix partition (call inside shard_map).

    Equal-width buckets over the mesh-wide ``[min, max]`` key range,
    collectively computed with one ``pmin``/``pmax`` pair — the ``range``
    mode without its static ``lo``/``hi`` hints, so it is usable (and
    autotunable) on data whose range nobody declared.  Monotone by
    construction: ``k1 <= k2`` implies ``bucket(k1) <= bucket(k2)``, which
    is all the exchange's contiguous bucket -> shard map needs for global
    sortedness.  Degenerate ranges (all keys equal) collapse into bucket 0;
    ±inf endpoints squash every finite key into one bucket — both *correct*
    (monotone) but maximally skewed, which is exactly the failure mode the
    sample partition exists to fix.

    >>> import jax, jax.numpy as jnp, repro
    >>> from jax.sharding import PartitionSpec as P
    >>> mesh = jax.make_mesh((jax.device_count(),), ("x",))
    >>> keys = jnp.arange(16.0)
    >>> f = jax.jit(jax.shard_map(
    ...     lambda k: radix_bucket_ids(k, 4, "x"),
    ...     mesh=mesh, in_specs=P("x"), out_specs=P("x")))
    >>> [int(b) for b in f(keys)]       # 16 keys, 4 equal-width buckets
    [0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3]
    """
    kf = keys.astype(jnp.float32)
    lo = jax.lax.pmin(jnp.min(kf), axis_name)
    hi = jax.lax.pmax(jnp.max(kf), axis_name)
    span = jnp.maximum(hi - lo, jnp.float32(np.finfo(np.float32).tiny))
    scaled = (kf - lo) * (n_buckets / span)
    # inf endpoints produce inf*0 / inf-inf NaNs; a NaN here can only come
    # from that degeneracy, and bucket 0 keeps the map monotone for the
    # finite keys (the where() pins below handle the infinities themselves)
    scaled = jnp.where(jnp.isnan(scaled), 0.0, scaled)
    b = jnp.clip(scaled, 0, n_buckets - 1).astype(jnp.int32)
    b = jnp.where(kf >= hi, n_buckets - 1, b)
    return jnp.where(kf <= lo, 0, b).astype(jnp.int32)


def splitter_bucket(keys: jax.Array, splitters: jax.Array) -> jax.Array:
    """bucket = rank of key among B-1 sorted splitters (plain samplesort).

    >>> import jax.numpy as jnp
    >>> spl = jnp.array([10, 20, 30])
    >>> [int(b) for b in splitter_bucket(jnp.array([5, 10, 25, 99]), spl)]
    [0, 1, 2, 3]
    """
    return jnp.searchsorted(splitters, keys, side="right").astype(jnp.int32)


def splitters_from_sample(
    sample, n_buckets: int, *, unique: bool = False
) -> jax.Array:
    """B-1 interior quantile splitters from a gathered key sample.

    The host-side half of splitter derivation, shared by
    ``choose_splitters`` (in-jit, fixed shapes) and tooling/tests that
    derive splitters from a numpy sample.  ``unique=True`` additionally
    deduplicates (numpy path only — dedup is data-dependent and cannot run
    under jit), returning possibly fewer than ``n_buckets - 1`` splitters;
    ``splitter_bucket`` then emits correspondingly fewer distinct buckets.
    Deterministic: the same sample always yields the same splitters.

    >>> import numpy as np
    >>> [int(s) for s in splitters_from_sample(np.arange(100), 4)]
    [25, 50, 75]
    >>> [int(s) for s in splitters_from_sample(
    ...     np.array([7, 7, 7, 7, 9]), 4, unique=True)]
    [7]
    """
    flat = jnp.sort(jnp.asarray(sample).reshape(-1))
    total = flat.shape[0]
    q = (jnp.arange(1, n_buckets) * total) // n_buckets
    spl = flat[q]
    if unique:
        return jnp.asarray(np.unique(np.asarray(spl)))
    return spl


def choose_splitters(
    local_keys: jax.Array,
    n_buckets: int,
    axis_name: str,
    *,
    oversample: int = 8,
) -> jax.Array:
    """Distributed quantile-splitter selection (samplesort), inside shard_map.

    Every device contributes ``oversample * n_buckets`` strided samples of
    its *sorted* shard; the all-gathered sample is sorted and B-1 quantiles
    become the splitters.  One small all_gather — negligible next to the
    data exchange.

    >>> import jax, jax.numpy as jnp, repro
    >>> from jax.sharding import PartitionSpec as P
    >>> mesh = jax.make_mesh((jax.device_count(),), ("x",))
    >>> f = jax.jit(jax.shard_map(
    ...     lambda k: choose_splitters(k, 4, "x"),
    ...     mesh=mesh, in_specs=P("x"), out_specs=P()))
    >>> spl = f(jnp.arange(64.0))
    >>> bool(jnp.all(spl[:-1] <= spl[1:]))     # sorted, B-1 of them
    True
    """
    m = local_keys.shape[-1]
    s = min(m, oversample * n_buckets)
    stride = max(1, m // s)
    local_sorted = jnp.sort(local_keys, axis=-1)
    sample = local_sorted[..., ::stride][..., :s]
    gathered = jax.lax.all_gather(sample, axis_name)  # (P, s)
    return splitters_from_sample(gathered, n_buckets)


def _composite_splitters(
    local_keys: jax.Array,
    gid: jax.Array,
    n_buckets: int,
    axis_name: str,
    oversample: int,
) -> Tuple[jax.Array, jax.Array]:
    """(key, id) quantile splitters over the gathered composite sample."""
    m = local_keys.shape[-1]
    s = min(m, oversample * n_buckets)
    stride = max(1, m // s)
    order = jnp.argsort(local_keys, stable=True)
    sk = local_keys[order][::stride][:s]
    sid = gid[order][::stride][:s]
    gk = jax.lax.all_gather(sk, axis_name).reshape(-1)
    gi = jax.lax.all_gather(sid, axis_name).reshape(-1)
    pos = jnp.lexsort((gi, gk))  # composite order: key major, id minor
    gk, gi = gk[pos], gi[pos]
    total = gk.shape[0]
    q = (jnp.arange(1, n_buckets) * total) // n_buckets
    return gk[q], gi[q]


def sample_partition_ids(
    local_keys: jax.Array,
    n_buckets: int,
    axis_name: str,
    *,
    oversample: int = DEFAULT_OVERSAMPLE,
    stable: bool = False,
) -> jax.Array:
    """Balanced bucket ids from composite ``(key, id)`` splitters.

    Plain key splitters cannot split a tie: an all-equal or duplicate-heavy
    distribution sends each whole tie run to a single bucket, and the slabs
    overflow no matter how well the splitters were chosen.  Here every
    element carries a unique id, the splitter space is the composite
    ``(key, id)`` — totally ordered, duplicate-free — and a bucket boundary
    can land *inside* a tie run, so bucket loads track the sample quantiles
    for every distribution.

    ``stable=False`` (keys-only sorts, where tie order is unobservable)
    interleaves ids across shards (``id = position * P + shard``), so even a
    globally constant key spreads each sender's elements evenly over all
    buckets.  ``stable=True`` (key-value sorts) uses arrival-order ids
    (``id = shard * m + position``): cross-bucket tie order then equals
    arrival order, and within a bucket the slab layout's (sender, slot)
    order is arrival order too — the stable-sort guarantee survives with
    bucket boundaries inside tie runs.  The cost: arrival ids are
    shard-contiguous, so a tie run still buckets shard-by-shard (balanced
    globally, not per sender).

    Monotone in the composite order, hence in key order:
    ``k1 <= k2`` implies ``bucket(k1) <= bucket(k2)``.

    >>> import jax, jax.numpy as jnp, repro
    >>> from jax.sharding import PartitionSpec as P
    >>> mesh = jax.make_mesh((jax.device_count(),), ("x",))
    >>> f = jax.jit(jax.shard_map(
    ...     lambda k: sample_partition_ids(k, 4, "x"),
    ...     mesh=mesh, in_specs=P("x"), out_specs=P("x")))
    >>> b = f(jnp.zeros(64, jnp.int32))        # all-equal keys still balance
    >>> [int(c) for c in jnp.bincount(b, length=4)]    # even to within one
    [17, 16, 16, 15]
    """
    P_ = jax.lax.axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    m = local_keys.shape[-1]
    pos = jnp.arange(m, dtype=jnp.int32)
    if stable:
        gid = idx * m + pos          # global arrival order (shard-major)
    else:
        gid = pos * P_ + idx         # shard-interleaved (balance-optimal)
    spl_k, spl_id = _composite_splitters(
        local_keys, gid, n_buckets, axis_name, oversample
    )
    k, i = local_keys[:, None], gid[:, None]
    above = (k > spl_k[None, :]) | ((k == spl_k[None, :]) & (i > spl_id[None, :]))
    return above.sum(axis=-1).astype(jnp.int32)
