"""Capacity-doubling retry driver shared by every exchange consumer.

``cluster_sort`` / ``cluster_sort_kv`` (model-D sort) and
``moe_apply_adaptive`` (MoE dispatch) all run their compiled exchange
through ``run_with_capacity_retries``: execute at the current capacity,
detect collective overflow, double and re-execute, and report the final
attempt's telemetry (peak per-(sender, bucket) count, overflow / retry /
recompile events) — the feedback ``repro.engine.adapt`` turns into learned
capacity factors so steady state never pays the retry again.
"""
from __future__ import annotations

import threading
from typing import Callable, Optional

__all__ = ["run_with_capacity_retries"]

# serializes the (miss-count snapshot, memoized construction) pairs inside
# run_with_capacity_retries so concurrent callers never attribute each
# other's cache misses to their own telemetry; construction is cheap (the
# jit wrapper — actual compilation happens at call time, outside the lock)
_RECOMPILE_COUNT_LOCK = threading.Lock()


def run_with_capacity_retries(
    make_fn: Callable[[int], Callable],
    run_fn: Callable[[Callable], tuple],
    *,
    m: int,
    part_buckets: int,
    cap: int,
    max_retries: int,
    telemetry: Optional[Callable[..., None]],
    lru,
    label: str,
    strict: bool = True,
    partition: Optional[str] = None,
):
    """Shared capacity-doubling retry driver for exchange-based paths.

    ``make_fn(cap)`` returns the compiled executable for one capacity (an
    ``lru_cache``-memoized factory — ``lru`` is that factory, used to count
    retry-forced fresh compilations); ``run_fn(fn)`` executes it and returns
    ``(*outputs, counts, peak, overflow)``.  On success returns
    ``(outputs, counts)`` — sort callers turn ``counts`` into a validity
    mask with ``slab_valid``, MoE callers read per-expert token counts.
    On persistent overflow, ``strict=True`` (the sort contract: losing keys
    is corruption) raises ``RuntimeError``; ``strict=False`` (the MoE
    contract: GShard-style overflow-drop is well-defined) returns the last
    attempt's outputs with the overflow already reported.  Either way the
    final attempt's telemetry (peak per-(sender, bucket) count, overflow/
    retry/recompile events) is reported through ``telemetry`` — the feedback
    ``repro.engine.adapt`` turns into learned capacity factors.

    >>> import jax.numpy as jnp
    >>> from functools import lru_cache
    >>> @lru_cache(maxsize=None)
    ... def make(cap):                     # "compile" for one capacity
    ...     return cap
    >>> def run(cap):                      # toy: overflows until cap >= 3
    ...     counts = jnp.array([3])
    ...     return jnp.zeros(4), counts, jnp.asarray(3), jnp.asarray(cap < 3)
    >>> outs, counts = run_with_capacity_retries(
    ...     make, run, m=8, part_buckets=1, cap=1, max_retries=4,
    ...     telemetry=None, lru=make, label="toy")
    >>> len(outs), int(counts[0])          # cap doubled 1 -> 2 -> 4, then fit
    (1, 3)
    """
    retries, peak, recompiles = 0, 0, 0

    def report(overflowed: bool) -> None:
        if telemetry is not None:
            telemetry(
                m=m,
                part_buckets=part_buckets,
                capacity=cap,
                peak=peak,
                overflowed=overflowed,
                retries=retries,
                recompiles=recompiles,
                partition=partition,
            )

    for attempt in range(max_retries + 1):
        if attempt:
            cap = min(m, cap * 2)
        with _RECOMPILE_COUNT_LOCK:
            misses0 = lru.cache_info().misses
            fn = make_fn(cap)
            fresh = lru.cache_info().misses - misses0
        if attempt:
            # only retry attempts count: a first-call warmup compile is the
            # normal cost of a new config, not an overflow-forced recompile
            recompiles += fresh
        *outs, counts, att_peak, overflow = run_fn(fn)
        peak = max(peak, int(att_peak))
        retries = attempt
        if not bool(overflow):
            report(overflowed=attempt > 0)
            return outs, counts
        if cap >= m:
            break  # already loss-free capacity; more retries can't help
    report(overflowed=True)
    if strict:
        raise RuntimeError(f"{label}: capacity overflow persisted after retries")
    return outs, counts
