"""The exchange wire: one fixed-capacity ``all_to_all`` each way.

``partition_exchange`` ships every element to the shard owning its bucket;
``combine_exchange`` is the exact inverse (MoE's return trip).  Buckets are
generic: model-D sort passes radix digits / splitter ranks, MoE dispatch
passes expert ids — same slabs, same overflow semantics, same telemetry
signal (``ExchangeResult.counts`` / ``.overflow``).

SPMD adaptation (DESIGN.md §2): MPI's variable-length messages become
fixed-capacity slabs of ``capacity`` elements per (src, dst) pair, padded
with sentinels.  Overflow is detected collectively and surfaced; capacity
policy lives one layer up (``retry.py`` doubles and retries,
``models/moe.py`` may drop, ``repro.engine.adapt`` learns).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from .partition import radix_bucket_ids, sample_partition_ids
from .slabs import sentinel_for

__all__ = ["ExchangeResult", "combine_exchange", "partition_exchange"]


@dataclass
class ExchangeResult:
    """Everything ``partition_exchange`` learned while scattering one batch.

    ``recv_*`` are what this shard received (slab layout, sentinel/zero
    padded); ``send_slot``/``counts``/``overflow`` describe what this shard
    sent — ``counts`` and ``overflow`` are the raw telemetry the adaptive
    capacity loop feeds on.

    >>> import jax.numpy as jnp
    >>> ex = ExchangeResult(recv_keys=jnp.zeros(4), recv_values=None,
    ...                     recv_src_slot=jnp.full(4, -1), send_slot=None,
    ...                     counts=jnp.array([3, 1]), overflow=False)
    >>> int(ex.counts.max()), bool(ex.overflow)
    (3, False)
    """

    recv_keys: jax.Array        # (P, C) keys received, sentinel-padded
    recv_values: Any            # pytree of (P, C, ...) or None
    recv_src_slot: jax.Array    # (P, C) flat slot id in the *sender's* slab
    send_slot: jax.Array        # (m,) my element's slab slot, -1 if dropped
    counts: jax.Array           # (n_buckets,) my element count per bucket
    overflow: jax.Array         # scalar bool: any (src,dst) bucket overflowed


def _stable_argsort_by(dest: jax.Array) -> jax.Array:
    """Stable order grouping elements by destination (XLA sort = local 'quicksort')."""
    return jnp.argsort(dest, stable=True)


def _quantize_rows(v: jax.Array):
    """bf16/f32 (N, ...) -> (int8 payload, f32 per-row scale) for the wire."""
    vf = v.astype(jnp.float32)
    flat = vf.reshape(v.shape[0], -1)
    scale = jnp.max(jnp.abs(flat), axis=-1) / 127.0
    q = jnp.round(vf / jnp.maximum(scale, 1e-12).reshape((-1,) + (1,) * (v.ndim - 1)))
    return q.astype(jnp.int8), scale


def _dequantize_rows(q: jax.Array, scale: jax.Array, dtype):
    return (
        q.astype(jnp.float32) * scale.reshape((-1,) + (1,) * (q.ndim - 1))
    ).astype(dtype)


def _compressed_a2a(axis_name: str, P_: int, row: int):
    """int8-on-the-wire all_to_all with a straight-through backward.

    Forward ships (int8 payload, f32 per-row scale) — ~0.53x the bf16 bytes.
    ``round`` has zero gradient, so the custom VJP routes cotangents through
    the (self-transpose) all_to_all uncompressed.
    """
    a2a = partial(
        jax.lax.all_to_all, axis_name=axis_name, split_axis=0, concat_axis=0, tiled=False
    )

    @jax.custom_vjp
    def qa2a(v):  # v: (P_*row, ...) flat slab
        q, s = _quantize_rows(v)
        rq = a2a(q.reshape((P_, row) + v.shape[1:]))
        rs = a2a(s.reshape(P_, row))
        return _dequantize_rows(
            rq.reshape((P_ * row,) + v.shape[1:]), rs.reshape(-1), v.dtype
        )

    def fwd(v):
        return qa2a(v), None

    def bwd(_, g):
        back = a2a(g.reshape((P_, row) + g.shape[1:]))
        return (back.reshape((P_ * row,) + g.shape[1:]),)

    qa2a.defvjp(fwd, bwd)
    return qa2a


def partition_exchange(
    keys: jax.Array,
    values: Any,
    bucket_ids: Optional[jax.Array],
    axis_name: str,
    *,
    capacity: int,
    n_buckets: Optional[int] = None,
    compress: bool = False,
    partition: Optional[str] = None,
    oversample: Optional[int] = None,
) -> ExchangeResult:
    """Ship every element to the shard owning its bucket (call inside shard_map).

    keys: (m,); values: pytree of (m, ...) moved alongside; bucket_ids: (m,)
    int32 in [0, n_buckets). ``n_buckets`` defaults to the axis size P and must
    be a multiple of it; buckets map to shards contiguously (shard =
    bucket * P // n_buckets) so bucket order == shard order (global sortedness
    / expert grouping both rely on this). ``capacity`` is per (sender, bucket).

    ``bucket_ids=None`` derives the ids in-graph from ``partition``:
    ``"radix"`` auto-ranged equal-width buckets, ``"sample"`` balanced
    composite splitters (``oversample`` tunes the sample size; values ride
    stably, so the sample partition uses arrival-order tie ids whenever
    ``values`` travel).  Passing explicit ``bucket_ids`` keeps the historic
    contract — MoE routers and custom partitioners are unaffected.

    ``compress=True`` ships *float* value payloads as int8 with a per-element
    f32 scale (beyond-paper: ~0.53x wire bytes for bf16 tokens; quantization
    is straight-through for autodiff — the dequantized values carry
    gradients). Integer leaves always travel uncompressed: quantization is
    lossy and would corrupt indices/ids.

    Returns slabs of shape (P, B_loc * capacity): row j = what shard j sent me,
    laid out as (B_loc, capacity) for my local buckets.

    >>> import jax, jax.numpy as jnp, repro
    >>> from jax.sharding import PartitionSpec as P
    >>> mesh = jax.make_mesh((jax.device_count(),), ("x",))
    >>> keys = jnp.arange(16, dtype=jnp.int32) % jax.device_count()
    >>> def body(k):  # bucket id == destination shard
    ...     ex = partition_exchange(k, None, k, "x", capacity=16)
    ...     return ex.recv_keys.reshape(-1), ex.overflow
    >>> recv, ovf = jax.jit(jax.shard_map(
    ...     body, mesh=mesh, in_specs=P("x"), out_specs=(P("x"), P())))(keys)
    >>> int((recv < 16).sum()), bool(ovf)   # all 16 keys arrived, no overflow
    (16, False)
    """
    P_ = jax.lax.axis_size(axis_name)
    m = keys.shape[-1]
    C = capacity
    B = P_ if n_buckets is None else n_buckets
    if B % P_:
        raise ValueError(f"n_buckets={B} must be a multiple of axis size {P_}")
    if bucket_ids is None:
        if partition == "radix":
            bucket_ids = radix_bucket_ids(keys, B, axis_name)
        elif partition == "sample":
            kw = {} if oversample is None else {"oversample": oversample}
            bucket_ids = sample_partition_ids(
                keys, B, axis_name, stable=values is not None, **kw
            )
        else:
            raise ValueError(
                f"bucket_ids=None needs partition in ('radix', 'sample'), got {partition!r}"
            )
    sent = sentinel_for(keys.dtype, largest=True)

    # --- group by bucket (stable: preserves arrival order per bucket) ---
    order = _stable_argsort_by(bucket_ids)
    sorted_bkt = bucket_ids[order]
    counts = jnp.bincount(bucket_ids, length=B).astype(jnp.int32)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    pos_in_bucket = jnp.arange(m, dtype=jnp.int32) - offsets[sorted_bkt]
    valid = pos_in_bucket < C
    slot_sorted = jnp.where(valid, sorted_bkt * C + pos_in_bucket, B * C)

    # --- build fixed-capacity send slab (scatter, OOB slots dropped) ---
    slab_keys = jnp.full((B * C,), sent, keys.dtype)
    slab_keys = slab_keys.at[slot_sorted].set(keys[order], mode="drop")

    def to_slab(v):
        buf = jnp.zeros((B * C,) + v.shape[1:], v.dtype)
        return buf.at[slot_sorted].set(v[order], mode="drop")

    slab_values = None if values is None else jax.tree.map(to_slab, values)

    # remember where each *original* element went (for combine_exchange)
    send_slot = (
        jnp.full((m,), -1, jnp.int32)
        .at[order]
        .set(jnp.where(valid, slot_sorted, -1).astype(jnp.int32))
    )
    # receiver-side validity mask rides along as slot ids (-1 = padding)
    slab_src_slot = (
        jnp.full((B * C,), -1, jnp.int32)
        .at[slot_sorted]
        .set(slot_sorted.astype(jnp.int32), mode="drop")
    )

    # --- the one MSD-radix all_to_all (paper Fig 4 arrow: master -> nodes) ---
    row = (B // P_) * C
    a2a = partial(
        jax.lax.all_to_all, axis_name=axis_name, split_axis=0, concat_axis=0, tiled=False
    )
    recv_keys = a2a(slab_keys.reshape(P_, row))
    recv_src_slot = a2a(slab_src_slot.reshape(P_, row))
    if values is None:
        recv_values = None
    elif compress:
        # int8 quantization is lossy and only meaningful for float payloads;
        # integer leaves (indices, ids) ship uncompressed to stay exact
        recv_values = jax.tree.map(
            lambda v: (
                _compressed_a2a(axis_name, P_, row)(v).reshape((P_, row) + v.shape[1:])
                if jnp.issubdtype(v.dtype, jnp.floating)
                else a2a(v.reshape((P_, row) + v.shape[1:]))
            ),
            slab_values,
        )
    else:
        recv_values = jax.tree.map(
            lambda v: a2a(v.reshape((P_, row) + v.shape[1:])), slab_values
        )

    overflow = jax.lax.pmax(jnp.max(counts) > C, axis_name)
    return ExchangeResult(
        recv_keys=recv_keys,
        recv_values=recv_values,
        recv_src_slot=recv_src_slot,
        send_slot=send_slot,
        counts=counts,
        overflow=overflow,
    )


def combine_exchange(
    processed: Any,
    ex: ExchangeResult,
    axis_name: str,
    *,
    fill=0,
) -> Any:
    """Inverse exchange: return processed (P, C, ...) slabs to their senders and
    restore original element order. Dropped (overflowed) elements get ``fill``.

    The MoE return trip — expert outputs ride back through the self-transpose
    ``all_to_all`` and land in the exact slots their tokens left from.

    >>> import jax, jax.numpy as jnp, repro
    >>> from jax.sharding import PartitionSpec as P
    >>> mesh = jax.make_mesh((jax.device_count(),), ("x",))
    >>> keys = jnp.arange(16, dtype=jnp.int32) % jax.device_count()
    >>> vals = jnp.arange(16.0)
    >>> def roundtrip(k, v):
    ...     ex = partition_exchange(k, v, k, "x", capacity=16)
    ...     return combine_exchange(ex.recv_values, ex, "x")
    >>> out = jax.jit(jax.shard_map(roundtrip, mesh=mesh,
    ...     in_specs=(P("x"), P("x")), out_specs=P("x")))(keys, vals)
    >>> [int(v) for v in out] == list(range(16))   # exact round-trip
    True
    """
    a2a = partial(
        jax.lax.all_to_all, axis_name=axis_name, split_axis=0, concat_axis=0, tiled=False
    )
    returned = jax.tree.map(a2a, processed)  # (P, C, ...) back in sender layout

    m = ex.send_slot.shape[0]

    def gather(v):
        flat = v.reshape((v.shape[0] * v.shape[1],) + v.shape[2:])
        safe = jnp.clip(ex.send_slot, 0, flat.shape[0] - 1)
        out = flat[safe]
        mask = (ex.send_slot >= 0).reshape((m,) + (1,) * (out.ndim - 1))
        return jnp.where(mask, out, jnp.asarray(fill, out.dtype))

    return jax.tree.map(gather, returned)
