"""repro.exchange — the unified adaptive exchange layer.

One implementation of "bucket, cap, all-to-all, retry-on-overflow, learn"
for every consumer in the codebase.  The paper's model D (one-step MSD-Radix
data distribution, ``core/cluster_sort.py``) and GShard/Switch-style MoE
expert dispatch (``models/moe.py``) are the same primitive wearing different
keys: an element (sort key / token) is assigned a bucket (radix digit /
expert id), shipped to the shard owning that bucket through a single
fixed-capacity ``all_to_all``, processed there (local sort / expert FFN),
and — for MoE — shipped back.  Both pay the same failure mode (a skewed
bucket distribution overflows the fixed slabs) and both feed the same
remedy (observed peak counts reported through ``ExchangeTelemetry`` become
learned capacity factors in the plan cache; see ``repro.engine.adapt``).

Modules:

slabs      : slab/capacity math — ``sentinel_for``, ``slab_capacity``,
             ``slab_geometry`` (model D), ``expert_capacity`` (MoE),
             ``slab_valid``
collective : the wire — ``partition_exchange`` / ``combine_exchange`` /
             ``ExchangeResult`` (single all_to_all each way, optional int8
             compression)
retry      : ``run_with_capacity_retries`` — the capacity-doubling retry
             driver with per-attempt recompile accounting
telemetry  : ``ExchangeObservation`` / ``ExchangeTelemetry`` — the ledger
             the learning loop feeds on
partition  : the bucket-assignment policy — ``radix_bucket_ids`` (auto-ranged
             equal-width) vs ``sample_partition_ids`` (composite-splitter
             samplesort, balanced under any skew), ``partition_of``
             classifying every partitioner mode into the two families the
             planner persists and the learner promotes between

See docs/exchange.md for the layer's design and the model-D-sort vs
MoE-dispatch comparison.
"""
from .collective import ExchangeResult, combine_exchange, partition_exchange
from .partition import (
    DEFAULT_OVERSAMPLE,
    PARTITION_MODES,
    choose_splitters,
    partition_of,
    radix_bucket_ids,
    sample_partition_ids,
    splitter_bucket,
    splitters_from_sample,
)
from .retry import run_with_capacity_retries
from .slabs import (
    expert_capacity,
    sentinel_for,
    slab_capacity,
    slab_geometry,
    slab_valid,
)
from .telemetry import ExchangeObservation, ExchangeTelemetry

# PARTITION_MODES / DEFAULT_OVERSAMPLE are importable constants but stay out
# of __all__: the docs gate doctests every __all__ export's docstring, and
# plain constants carry their type's docstring
__all__ = [
    "ExchangeObservation",
    "ExchangeResult",
    "ExchangeTelemetry",
    "choose_splitters",
    "combine_exchange",
    "expert_capacity",
    "partition_exchange",
    "partition_of",
    "radix_bucket_ids",
    "run_with_capacity_retries",
    "sample_partition_ids",
    "sentinel_for",
    "slab_capacity",
    "slab_geometry",
    "slab_valid",
    "splitter_bucket",
    "splitters_from_sample",
]
