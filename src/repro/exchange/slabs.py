"""Slab and capacity math shared by model-D sort and MoE dispatch.

SPMD has no ragged sends, so every exchange ships fixed-capacity,
sentinel-padded slabs per (sender, bucket) pair.  All capacity rounding in
the codebase flows through ``slab_capacity`` — ``slab_geometry`` (model-D
sort) and ``expert_capacity`` (MoE dispatch) are two keyings of the same
formula, so the two paths can never drift apart.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "expert_capacity",
    "sentinel_for",
    "slab_capacity",
    "slab_geometry",
    "slab_valid",
]


def sentinel_for(dtype, *, largest: bool):
    """Value that sorts after (largest) / before (smallest) all real keys —
    what exchange slabs and sort paddings are filled with.

    >>> import jax.numpy as jnp
    >>> int(sentinel_for(jnp.int32, largest=True)) == jnp.iinfo(jnp.int32).max
    True
    >>> float(sentinel_for(jnp.float32, largest=False))
    -inf
    """
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        v = jnp.inf if largest else -jnp.inf
    elif jnp.issubdtype(dtype, jnp.integer):
        info = jnp.iinfo(dtype)
        v = info.max if largest else info.min
    else:
        raise TypeError(f"unsupported key dtype {dtype}")
    return jnp.asarray(v, dtype)


def slab_capacity(m: int, buckets: int, capacity_factor: float) -> int:
    """Per-(sender, bucket) slab capacity — THE capacity formula.

    A uniform sender spreads its ``m`` elements evenly, ~``m / buckets``
    per bucket; ``capacity_factor`` is the over-provisioning margin on top.
    Clamped below by 1 slot (a zero-capacity slab can never drain — and the
    retry driver's capacity doubling would pin 0 forever) and above by ``m``
    (one sender cannot put more than all its elements into a single bucket —
    ``capacity == m`` is the loss-free guarantee both the model-D retry
    driver and the MoE drop path rely on).  The 1-slot floor wins over the
    ``m`` ceiling for an *empty* sender: a drained rank (``m == 0``) still
    ships well-formed 1-slot slabs through the collective.

    >>> slab_capacity(1000, 8, 1.5)     # ceil(1500 / 8)
    188
    >>> slab_capacity(64, 4, 8.0)       # clamped to the loss-free bound m
    64
    >>> slab_capacity(64, 4, 0.001)     # floored at one slot
    1
    >>> slab_capacity(0, 8, 1.25)       # empty sender: floor beats the bound
    1
    """
    return max(1, min(m, -(-int(capacity_factor * m) // max(buckets, 1))))


def slab_geometry(mode: str, m: int, P_: int, capacity_factor: float):
    """Exchange geometry for model D: (part_buckets, n_buckets, capacity).

    ``part_buckets`` is what the partitioner emits (10 in the paper's decimal
    mode, P otherwise); ``n_buckets`` rounds it up to the nearest multiple of
    P so ``partition_exchange``'s ``B % P == 0`` contract holds for any node
    count (buckets 10..n_buckets-1 simply stay empty).  ``capacity`` is sized
    per *bucket* via ``slab_capacity`` — a uniform load puts ~m/part_buckets
    keys in each (sender, bucket) pair, so deriving it from P (the old
    behaviour) under-provisioned exactly when buckets outnumber shards.

    >>> slab_geometry("decimal", 1000, 4, 2.0)
    (10, 12, 200)
    >>> slab_geometry("splitters", 1000, 8, 1.5)
    (8, 8, 188)
    """
    part_buckets = 10 if mode == "decimal" else P_
    n_buckets = -(-part_buckets // P_) * P_
    return part_buckets, n_buckets, slab_capacity(m, part_buckets, capacity_factor)


def expert_capacity(tokens: int, top_k: int, n_experts: int,
                    capacity_factor: float) -> int:
    """Per-(sender, expert) token capacity for MoE dispatch.

    The MoE keying of ``slab_capacity``: a sender dispatches
    ``tokens * top_k`` (token, expert) assignments over ``n_experts``
    buckets.  Hoisted here so the GShard-style formula in ``models/moe.py``
    shares the sort path's rounding rules exactly (same ceil, same
    [1, m] clamp) instead of drifting as a re-derived copy.

    >>> expert_capacity(32, 2, 4, 2.0)      # ceil(2.0 * 64 / 4)
    32
    >>> expert_capacity(32, 2, 4, 0.01)     # floors at one slot
    1
    >>> expert_capacity(32, 2, 4, 8.0)      # clamped to tokens * top_k
    64
    >>> expert_capacity(0, 2, 8, 1.25)      # empty shard/microbatch: never 0
    1
    """
    return slab_capacity(tokens * top_k, n_experts, capacity_factor)


def slab_valid(total: int, counts, P_: int):
    """Validity mask over a gathered (P_ * C_total,) result slab.

    ``counts[p]`` is shard p's real element count; entries past it in shard
    p's ``C_total``-slot range are sentinel/zero padding.  This is how the
    retry driver's callers turn per-shard counts into the dense mask the
    engine compacts slabs with.

    >>> import jax.numpy as jnp
    >>> [bool(b) for b in slab_valid(4, jnp.array([1, 2]), 2)]
    [True, False, True, True]
    """
    C_total = total // P_
    pos = jnp.arange(total) % C_total
    return pos < jnp.repeat(counts, C_total)
