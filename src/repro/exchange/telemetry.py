"""Exchange telemetry: the observation schema and the thread-safe ledger.

Every adaptive exchange call — model-D ``cluster_sort``/``cluster_sort_kv``
and MoE ``moe_apply_adaptive`` — reports one ``ExchangeObservation`` per
call (max observed per-(sender, bucket) count, overflow/retry/recompile/
drop events) into an ``ExchangeTelemetry`` ledger keyed by plan-cache cell.
``repro.engine.adapt``'s ``CapacityLearner`` folds the history into learned
capacity factors the ``Planner`` persists; docs/exchange.md documents the
schema and the loop end to end.
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass
from typing import Dict, Optional

__all__ = ["ExchangeObservation", "ExchangeTelemetry"]


@dataclass(frozen=True)
class ExchangeObservation:
    """One exchange call's telemetry (sort or MoE dispatch).

    ``peak`` is the max per-(sender, bucket) element count observed across
    the mesh — the quantity slab capacity must cover.  ``required_factor``
    converts it back into the smallest ``capacity_factor`` whose
    ``slab_capacity`` would have fit the call without overflow.  For MoE
    dispatch the fields read: m = tokens x top_k assignments per sender,
    part_buckets = n_experts, peak = hottest expert's per-sender token
    count, and ``dropped`` counts tokens an overflowed attempt dropped
    (averted by the retry on the adaptive path, real output drops on the
    fixed-capacity path).

    >>> obs = ExchangeObservation(m=128, part_buckets=8, capacity=32,
    ...                           peak=48, overflowed=True, retries=1)
    >>> obs.required_factor()
    3.0
    >>> obs.peak_mean_ratio()              # 3x the mean bucket load: skewed
    3.0
    >>> obs.dropped, obs.dropped_averted   # sorts never drop; MoE may
    (0, 0)
    >>> obs.partition is None              # caller didn't tag the family
    True
    """

    m: int                  # per-shard element count
    part_buckets: int       # buckets the partitioner emits
    capacity: int           # slab capacity of the final (successful) attempt
    peak: int               # max per-(src, dst) bucket count seen
    overflowed: bool        # any attempt overflowed
    retries: int            # capacity-doubling retries this call paid
    recompiles: int = 0     # fresh executables those retries compiled
    dropped: int = 0        # elements the *served* output lost (MoE fixed /
    #                         retry-exhausted path: final attempt overflowed)
    dropped_averted: int = 0  # elements retried attempts would have lost
    #                           (recomputed loss-free, so not in the output)
    partition: Optional[str] = None  # partition family that produced the
    #                                  bucket ids ("radix"/"sample"); None for
    #                                  callers outside the policy (e.g. MoE,
    #                                  where the router is the partitioner)

    def required_factor(self) -> float:
        """Smallest ``capacity_factor`` that fits ``peak`` without overflow."""
        return self.peak * self.part_buckets / max(self.m, 1)

    def peak_mean_ratio(self) -> float:
        """Peak bucket load over the mean bucket load (``m / part_buckets``).

        The skew signal: 1.0 is a perfectly balanced partition, and the
        ``CapacityLearner`` promotes a persistently-radix key to the sample
        partition when this stays above its ``promote_ratio``.  Numerically
        identical to ``required_factor`` — capacity need *is* peak/mean —
        but named for what promotion decisions actually read.

        >>> ExchangeObservation(m=64, part_buckets=8, capacity=16, peak=8,
        ...                     overflowed=False, retries=0).peak_mean_ratio()
        1.0
        """
        return self.required_factor()


class ExchangeTelemetry:
    """Thread-safe ledger of exchange observations, keyed by plan-cache cell.

    Keeps a bounded rolling window of observations per key plus lifetime
    totals (calls, overflow events, retries, recompiles, dropped elements)
    so long-lived serving processes report recent behaviour and cumulative
    cost.

    >>> led = ExchangeTelemetry()
    >>> led.record("4096|int32|local/cpu", ExchangeObservation(
    ...     m=128, part_buckets=8, capacity=32, peak=48,
    ...     overflowed=True, retries=1))
    >>> led.last("4096|int32|local/cpu").retries
    1
    >>> led.overflow_events, led.total_retries, led.total_dropped
    (1, 1, 0)
    """

    def __init__(self, window: int = 256):
        self._window = window
        self._obs: Dict[str, deque] = {}
        self._lock = threading.Lock()
        self._subscribers: list = []
        self.calls = 0
        self.overflow_events = 0
        self.total_retries = 0
        self.total_recompiles = 0
        self.total_dropped = 0
        self.total_dropped_averted = 0

    def subscribe(self, fn) -> None:
        """Register ``fn(key, obs)`` to run after every ``record``.

        Subscribers run outside the ledger lock (they may read the ledger
        back).  This is how ``AnomalyMonitor.watch_exchange`` folds served
        MoE drops into the routing-collapse signal without the exchange
        layer importing the fault-tolerance layer.
        """
        with self._lock:
            self._subscribers.append(fn)

    def record(self, key: str, obs: ExchangeObservation) -> None:
        with self._lock:
            self._obs.setdefault(key, deque(maxlen=self._window)).append(obs)
            self.calls += 1
            self.overflow_events += int(obs.overflowed)
            self.total_retries += obs.retries
            self.total_recompiles += obs.recompiles
            self.total_dropped += obs.dropped
            self.total_dropped_averted += obs.dropped_averted
            subscribers = list(self._subscribers)
        for fn in subscribers:
            fn(key, obs)

    def last(self, key: str) -> Optional[ExchangeObservation]:
        """Most recent observation for ``key`` (None before any call)."""
        with self._lock:
            window = self._obs.get(key)
            return window[-1] if window else None

    def peak_factor(self, key: str) -> float:
        """Largest ``required_factor`` in ``key``'s rolling window (0.0 if
        the key has never been observed)."""
        with self._lock:
            window = self._obs.get(key, ())
            return max((o.required_factor() for o in window), default=0.0)

    def last_ratio(self, key: str) -> float:
        """Most recent ``peak_mean_ratio`` for ``key`` (0.0 before any call).

        The per-key skew signal promotion decisions read — exposed here so
        operators and tests observe it without touching learner internals.
        """
        obs = self.last(key)
        return obs.peak_mean_ratio() if obs is not None else 0.0

    def keys(self):
        with self._lock:
            return sorted(self._obs)
