"""Logical-axis sharding rules -> PartitionSpecs for params / opt state / data.

Parallelism profile (DESIGN.md §5): batch over ("pod","data"); heads / experts
/ ffn-hidden over "model"; parameters 2-D sharded over ("data","model") —
FSDP×TP, XLA inserts the gathers. Optimizer moments follow their param's spec
(int8 moments are flat (nb,128) blocks -> sharded on the block axis over
"data"). Parameters are replicated across pods (grad all-reduce over "pod" is
the only DCN traffic).

Rules are name-based on the param tree paths produced by models/transformer.py;
every leaf gets a spec, unknown large leaves fail loudly.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


def _path_names(kp) -> tuple:
    out = []
    for k in kp:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "name"):
            out.append(str(k.name))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
    return tuple(out)


def _param_spec(names: tuple, leaf) -> P:
    nd = getattr(leaf, "ndim", 0)
    grouped = names and names[0] == "blocks"  # stacked (G, ...) leaves
    lead = (None,) if grouped else ()
    n = set(names)

    def spec(*axes):
        full = lead + tuple(axes)
        assert len(full) == nd, (names, nd, full)
        return P(*full)

    if "table" in n:  # embedding (V, D): vocab-parallel (Megatron), D replicated
        return spec("model", None)
    if "router" in n:  # (D, E) small, replicated
        return spec(*([None] * (nd - len(lead))))
    # MoE expert stacks: (E, D, F) / (E, F, D)
    if nd - len(lead) == 3 and ("w_in" in n or "w_gate" in n):
        return spec("model", "data", None)
    if nd - len(lead) == 3 and "w_out" in n:
        return spec("model", None, "data")
    if names[-1] == "w":
        parent = names[-2]
        if parent in ("wq", "wk", "wv", "w_in", "w_gate", "in_proj"):
            return spec("data", "model")
        if parent in ("wo", "w_out", "out_proj"):
            return spec("model", "data")
    if names[-1] == "b":
        parent = names[-2]
        if parent in ("wq", "wk", "wv", "w_in", "w_gate", "in_proj"):
            return spec("model")
        return spec(None)
    if "conv_w" in n:
        return spec(None, "model")
    if "conv_b" in n:
        return spec("model")
    # norms / scalars / small vectors (A_log, D_skip, dt_bias, scale)
    small = (None,) * (nd - len(lead))
    return spec(*small)


def param_specs(params) -> Any:
    """PartitionSpec pytree matching ``params`` (works on ShapeDtypeStructs)."""
    return jax.tree_util.tree_map_with_path(
        lambda kp, leaf: _param_spec(_path_names(kp), leaf), params
    )


def opt_state_specs(state, pspecs) -> Any:
    """Specs for the optimizer state given the param specs.

    fp32 moments / error-feedback buffers mirror the param spec; int8
    quantized moments {"q","scale"} shard their block axis over "data" (ZeRO-1
    style); count is replicated.
    """
    from repro.optim.adamw import _is_q

    def match(sub):
        return jax.tree.map(
            lambda _, s: s, sub, pspecs
        )

    out = {}
    for key, val in state.items():
        if key == "count":
            out[key] = P()
        elif key in ("m", "v"):
            def q_or_p(leaf_state, spec):
                if _is_q(leaf_state):
                    # rowwise int8: q shards exactly like its param; scale
                    # drops the last (quantized) axis
                    return {"q": spec, "scale": P(*spec[:-1])}
                return spec
            out[key] = jax.tree.map(q_or_p, val, pspecs, is_leaf=_is_q)
        else:  # err buffers
            out[key] = pspecs
    return out


def cache_specs(cache, cfg) -> Any:
    """Specs for the decode cache: batch over ("pod","data"); the *sequence*
    dim of KV caches shards over "model" (flash-decoding split-K across chips:
    XLA turns the sharded-contraction softmax into cheap partial-reduce
    all-reduces); Mamba states shard heads/channels over "model"."""
    bt = ("pod", "data")

    def one(kp, leaf):
        names = _path_names(kp)
        pos = int(names[0][3:])  # "posN"
        kind = cfg.pattern[pos]
        nd = leaf.ndim
        if kind.startswith("attn"):
            if nd == 5:  # (G, B, S, Hk, hd) k or v
                return P(None, bt, "model", None, None)
            return P(None)  # (G,) length
        if nd == 4:  # (G, B, k-1, conv_dim)
            return P(None, bt, None, "model")
        return P(None, bt, "model", None, None)  # (G, B, nh, ds, hp)

    return jax.tree_util.tree_map_with_path(one, cache)


def batch_specs(batch: dict) -> Any:
    """Input batch: leading (global batch) dim over ("pod","data")."""
    def one(leaf):
        nd = getattr(leaf, "ndim", 0)
        return P(("pod", "data"), *([None] * (nd - 1)))

    return jax.tree.map(one, batch)


def fit_spec(shape, spec: P, mesh) -> P:
    """Drop mesh axes that don't exist or don't divide the dim (B=1 decode)."""
    valid = set(mesh.axis_names)
    out = []
    for dim, a in enumerate(spec):
        if a is None:
            out.append(None)
            continue
        axes = a if isinstance(a, tuple) else (a,)
        kept, rem = [], shape[dim]
        for ax in axes:
            if ax in valid and rem % mesh.shape[ax] == 0:
                kept.append(ax)
                rem //= mesh.shape[ax]
        out.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    return P(*out)


def to_named(tree_specs, mesh, like=None) -> Any:
    """Specs -> NamedShardings; with ``like`` (shape tree), fit per-dim."""
    if like is None:
        valid = set(mesh.axis_names)

        def fix(s):
            def ok(a):
                if a is None:
                    return None
                if isinstance(a, tuple):
                    kept = tuple(x for x in a if x in valid)
                    return kept if kept else None
                return a if a in valid else None

            return NamedSharding(mesh, P(*(ok(a) for a in s)))

        return jax.tree.map(fix, tree_specs, is_leaf=lambda x: isinstance(x, P))
    return jax.tree.map(
        lambda s, l: NamedSharding(mesh, fit_spec(l.shape, s, mesh)),
        tree_specs,
        like,
        is_leaf=lambda x: isinstance(x, P),
    )
