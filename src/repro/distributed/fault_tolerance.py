"""Fault tolerance: watchdog, retry-from-checkpoint, anomaly monitors.

What "runs on 1000+ nodes" means for the control plane (DESIGN.md §5):

* ``StepWatchdog`` — per-step wall-clock deadline. A straggling/hung step
  (dead host, stuck collective) raises ``StepTimeout`` instead of wedging the
  job; the driver restores the last checkpoint and continues. On real pods
  the deadline maps to the coordinator's barrier timeout.
* ``run_with_recovery`` — the restart loop: run steps, checkpoint every K,
  on StepTimeout / anomaly restore + replay (bit-exact: pipeline state is in
  the checkpoint). ``max_restarts`` bounds flapping. Elastic rescale is the
  same path with a different mesh at restore (checkpoints are mesh-agnostic).
* ``AnomalyMonitor`` — NaN/inf loss, exploding grad-norm, and MoE capacity
  overflow (routing collapse) counters; each trips recovery rather than
  silently corrupting the run.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import numpy as np


class StepTimeout(RuntimeError):
    pass


class TrainingAnomaly(RuntimeError):
    pass


class StepWatchdog:
    """Context manager enforcing a wall-clock deadline on one step."""

    def __init__(self, seconds: float):
        self.seconds = seconds
        self._timer: Optional[threading.Timer] = None
        self._expired = threading.Event()

    def __enter__(self):
        self._timer = threading.Timer(self.seconds, self._expired.set)
        self._timer.start()
        return self

    def __exit__(self, *exc):
        assert self._timer is not None
        self._timer.cancel()
        if self._expired.is_set() and exc[0] is None:
            raise StepTimeout(f"step exceeded {self.seconds}s deadline")
        return False

    @property
    def expired(self) -> bool:
        return self._expired.is_set()


@dataclass
class AnomalyMonitor:
    grad_norm_limit: float = 1e4
    overflow_patience: int = 10      # consecutive MoE-overflow steps tolerated
    _overflow_streak: int = 0
    _pending_dropped: int = 0        # served drops reported since last check()
    _dropped_total: int = 0
    # exchange observations arrive from whichever thread ran the dispatch
    # (sync callers, the async queue's dispatcher, concurrent warmups), so
    # the drop counters must not lose updates to read-modify-write races
    _drop_lock: threading.Lock = field(default_factory=threading.Lock, repr=False)

    def watch_exchange(self, telemetry: Any) -> "AnomalyMonitor":
        """Subscribe to an ``ExchangeTelemetry`` ledger's observation stream.

        Each ``ExchangeObservation.dropped`` (tokens the *served* MoE output
        actually lost — fixed-capacity or retry-exhausted dispatch) accrues
        into a pending counter that the next ``check`` treats as an
        ``moe_overflow`` step even when the training metrics themselves
        don't carry the flag.  Averted drops (loss-free retries) don't
        count: the routing-collapse signal is about corrupted output, not
        about retry cost.  Returns self so construction chains.
        """
        telemetry.subscribe(self._on_exchange)
        return self

    def _on_exchange(self, key: str, obs: Any) -> None:
        dropped = int(getattr(obs, "dropped", 0))
        if dropped > 0:
            with self._drop_lock:
                self._pending_dropped += dropped
                self._dropped_total += dropped

    @property
    def dropped_total(self) -> int:
        """Lifetime served-output drops seen via ``watch_exchange``."""
        with self._drop_lock:
            return self._dropped_total

    def check(self, metrics: dict) -> None:
        loss = float(metrics.get("loss", 0.0))
        if not np.isfinite(loss):
            raise TrainingAnomaly(f"non-finite loss {loss}")
        gn = float(metrics.get("grad_norm", 0.0))
        if gn > self.grad_norm_limit:
            raise TrainingAnomaly(f"grad norm {gn:.3e} above limit")
        with self._drop_lock:
            dropped, self._pending_dropped = self._pending_dropped, 0
        if bool(metrics.get("moe_overflow", False)) or dropped > 0:
            self._overflow_streak += 1
            if self._overflow_streak >= self.overflow_patience:
                raise TrainingAnomaly(
                    f"MoE capacity overflow for {self._overflow_streak} consecutive "
                    f"steps (routing collapse; {self._dropped_total} tokens dropped "
                    "from served output) — raise capacity_factor or restore"
                )
        else:
            self._overflow_streak = 0


def run_with_recovery(
    *,
    n_steps: int,
    step_fn: Callable[[int], dict],            # runs step i, returns metrics
    save_fn: Callable[[int], None],            # checkpoint at step i
    restore_fn: Callable[[], int],             # restore; returns resume step
    checkpoint_every: int = 50,
    step_deadline_s: float = 3600.0,
    max_restarts: int = 3,
    monitor: Optional[AnomalyMonitor] = None,
) -> dict:
    """The production training control loop, minus the cluster scheduler.

    Returns summary {steps_run, restarts, last_metrics}.
    """
    monitor = monitor or AnomalyMonitor()
    restarts = 0
    step = 0
    last_metrics: dict = {}
    while step < n_steps:
        try:
            with StepWatchdog(step_deadline_s):
                last_metrics = step_fn(step)
            monitor.check(last_metrics)
            step += 1
            if step % checkpoint_every == 0 or step == n_steps:
                save_fn(step)
        except (StepTimeout, TrainingAnomaly):
            restarts += 1
            if restarts > max_restarts:
                raise
            step = restore_fn()
    return {"steps_run": step, "restarts": restarts, "last_metrics": last_metrics}
