"""Public sort API — a thin wrapper over the autotuned plan engine.

``sort(x)``                      -> planner-selected path (tuned plan if the
                                    engine has one for this size/dtype/mesh,
                                    else the paper's default rule: model B on
                                    one device, model D on a mesh)
``sort(x, mesh=..., axis=...)``  -> model D cluster sort (production path)
``strategy=`` overrides: 'shared' / 'shared_hybrid' (B), 'shared_merge' (A),
'distributed_merge' (C), 'cluster' (D) — these bypass the planner's plan
*selection*. Cluster runs on a mesh still close the capacity-learning loop
through the default planner (learned ``capacity_factor`` + telemetry) unless
``capacity_factor=`` / ``telemetry=`` — or a full ``plan=``, which pins its
own ``capacity_factor`` — are passed explicitly.
``local_impl=`` / ``block_n=`` further override the per-partition sequential
sort of whichever plan is selected (e.g. ``local_impl='pallas'`` routes every
local sort through the VMEM-tiled Pallas kernel).

Key-value sorting, argsort, and the batched serving front door live in
``repro.engine`` (kv.py / service.py).
"""
from __future__ import annotations

from typing import Optional

import jax

__all__ = ["sort"]


def sort(
    x: jax.Array,
    *,
    mesh=None,
    axis: Optional[str] = None,
    strategy: Optional[str] = None,
    plan=None,
    local_impl: Optional[str] = None,
    block_n: Optional[int] = None,
    n_threads: int = 8,
    ascending: bool = True,
    **kwargs,
):
    """Sort the last axis of ``x`` using one of the paper's parallel models.

    Precedence: explicit ``strategy=`` > explicit ``plan=`` (a
    ``repro.engine.SortPlan``) > tuned plan from the default planner >
    the paper's hard-coded rule.  ``local_impl=`` / ``block_n=`` rewrite the
    selected plan's local-sort fields whichever way it was chosen.

    Cluster plans close the capacity-learning loop by default: the call
    reports its exchange telemetry to the default planner and runs at that
    planner's learned ``capacity_factor`` for this (size, dtype, mesh) cell,
    so a workload that overflowed once never pays the overflow-retry
    recompile again.  Passing ``capacity_factor=`` / ``telemetry=`` — or an
    explicit ``plan=``, which pins the whole recipe including its
    ``capacity_factor`` — opts the call out of the loop, reading and
    writing (see repro.engine.adapt).

    >>> import jax.numpy as jnp
    >>> [int(v) for v in sort(jnp.array([3, 1, 2]))]
    [1, 2, 3]
    >>> [int(v) for v in sort(jnp.array([3, 1, 2]), strategy="shared",
    ...                       local_impl="pallas", n_threads=2)]
    [1, 2, 3]
    """
    from dataclasses import replace

    from repro.engine.planner import default_planner, plan_from_strategy, run_plan

    # an explicit plan= pins the full recipe — including capacity_factor —
    # so it must neither read nor mutate the learned table below (strategy=
    # only names a model family and keeps the loop on)
    pinned_plan = plan is not None and strategy is None
    if strategy is not None:
        plan = plan_from_strategy(strategy, n_threads=n_threads)
    elif plan is None:
        plan = default_planner().lookup(x.shape[-1], x.dtype, mesh)
        # with mesh= the documented return contract is cluster_sort's
        # (slab, valid) — only an explicit strategy=/plan= may change it, so
        # tuned non-cluster plans don't apply here
        if mesh is not None and (plan is None or plan.strategy != "cluster"):
            plan = plan_from_strategy("cluster")
        elif plan is None:  # pre-engine rule, honouring the n_threads argument
            plan = plan_from_strategy("shared_hybrid", n_threads=n_threads)
    if local_impl is not None:
        plan = replace(plan, local_impl=local_impl)
    if block_n is not None:
        plan = replace(plan, block_n=block_n)
    if (
        plan.strategy == "cluster"
        and mesh is not None
        and not pinned_plan
        and "capacity_factor" not in kwargs
        and "telemetry" not in kwargs
    ):
        # close the feedback loop: run at the learned capacity factor and
        # report this call's exchange telemetry back to the planner.  An
        # explicit capacity_factor=, telemetry=, or plan= opts out of the
        # WHOLE loop — a pinned experiment must neither read nor mutate the
        # process-wide learned state
        # mode=kwargs.get("mode") is the hint that keeps an explicit caller
        # mode authoritative; with no explicit mode, a skew-promoted cell
        # comes back with "mode": "sample" injected alongside the kwargs
        kwargs.update(
            default_planner().cluster_kwargs(
                x.shape[-1],
                x.dtype,
                mesh,
                default=plan.capacity_factor,
                mode=kwargs.get("mode"),
            )
        )
    return run_plan(plan, x, mesh=mesh, axis=axis, ascending=ascending, **kwargs)
