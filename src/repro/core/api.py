"""Public sort API — strategy dispatch over the paper's four models.

``sort(x)``                      -> fastest single-device path (model B)
``sort(x, mesh=..., axis=...)``  -> model D cluster sort (production path)
``strategy=`` overrides: 'shared_merge' (A), 'shared_hybrid' (B),
'distributed_merge' (C), 'cluster' (D).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .cluster_sort import cluster_sort
from .distributed_sort import distributed_merge_sort
from .shared_sort import shared_memory_sort

__all__ = ["sort"]

_STRATEGIES = ("shared_merge", "shared_hybrid", "distributed_merge", "cluster")


def sort(
    x: jax.Array,
    *,
    mesh=None,
    axis: Optional[str] = None,
    strategy: Optional[str] = None,
    n_threads: int = 8,
    ascending: bool = True,
    **kwargs,
):
    """Sort the last axis of ``x`` using one of the paper's parallel models."""
    if strategy is None:
        strategy = "cluster" if mesh is not None else "shared_hybrid"
    if strategy not in _STRATEGIES:
        raise ValueError(f"strategy must be one of {_STRATEGIES}")
    if strategy == "shared_merge":
        return shared_memory_sort(
            x, n_threads=n_threads, local_impl="merge", ascending=ascending
        )
    if strategy == "shared_hybrid":
        return shared_memory_sort(
            x, n_threads=n_threads, local_impl="xla", ascending=ascending
        )
    if mesh is None or axis is None:
        raise ValueError(f"strategy {strategy!r} requires mesh= and axis=")
    if strategy == "distributed_merge":
        out = distributed_merge_sort(x, mesh, axis, **kwargs)
        return out if ascending else jnp.flip(out, -1)
    slab, valid = cluster_sort(x, mesh, axis, **kwargs)
    return slab, valid
