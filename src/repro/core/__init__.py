"""repro.core — the paper's contribution: hierarchical hybrid parallel sort.

Model A/B (shared memory)  -> shared_sort.shared_memory_sort
Model C   (distributed)    -> distributed_sort.distributed_merge_sort
Model D   (cluster/hybrid) -> cluster_sort.cluster_sort  (production path)
Dispatch primitives reused by MoE: cluster_sort.partition_exchange/combine_exchange
"""
from .api import sort
from .bitonic import bitonic_merge_pair, bitonic_sort, bitonic_topk
from .cluster_sort import (
    ExchangeResult,
    cluster_sort,
    combine_exchange,
    partition_exchange,
)
from .distributed_sort import distributed_merge_sort
from .merge import merge_adjacent, merge_sorted_pair, rank_merge_pairs
from .radix import (
    choose_splitters,
    decimal_msd_bucket,
    make_partitioner,
    range_bucket,
    splitter_bucket,
)
from .seqsort import (
    LOCAL_SORTS,
    fast_local_sort,
    nonrecursive_merge_sort,
    pallas_local_sort,
    recursive_merge_sort_host,
)
from .shared_sort import shared_memory_sort

__all__ = [
    "sort",
    "bitonic_sort",
    "bitonic_merge_pair",
    "bitonic_topk",
    "cluster_sort",
    "partition_exchange",
    "combine_exchange",
    "ExchangeResult",
    "distributed_merge_sort",
    "merge_adjacent",
    "merge_sorted_pair",
    "rank_merge_pairs",
    "shared_memory_sort",
    "nonrecursive_merge_sort",
    "recursive_merge_sort_host",
    "fast_local_sort",
    "pallas_local_sort",
    "LOCAL_SORTS",
    "choose_splitters",
    "decimal_msd_bucket",
    "range_bucket",
    "splitter_bucket",
    "make_partitioner",
]
