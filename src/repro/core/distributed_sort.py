"""Paper model C: Distributed Memory Parallel Hybrid Quicksort and Merge Sort.

MPI nodes -> mesh devices; MPI send/recv -> ``jax.lax.ppermute`` inside
``shard_map``. The schedule is Fig 3 verbatim:

  1. every node sorts its partition with the fast local sort ("Quicksort"),
  2. log2(P) rounds: node ``i`` with ``i % 2^(r+1) == 2^r`` ships its whole
     buffer to node ``i - 2^r``, which merges it into its own buffer,
  3. after the last round node 0 holds the fully sorted data.

We keep the paper's flaw on purpose (DESIGN.md §7): every device must hold an
n-sized buffer and half the active devices idle each round — this is the
*faithful distributed baseline* that model D (cluster_sort.py) beats. SPMD has
no variable-length sends, so idle devices carry sentinel-padded buffers and the
merge happens unconditionally with a ``where`` select (uniform cost, same as
the paper's lock-step rounds).
"""
from __future__ import annotations

from functools import lru_cache, partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .bitonic import sentinel_for
from .merge import merge_sorted_pair
from .seqsort import fast_local_sort

__all__ = ["distributed_merge_sort", "merge_tree_local"]


def merge_tree_local(
    local: jax.Array,
    axis_name: str,
    *,
    local_impl: str = "xla",
    block_n: int | None = None,
):
    """Body to run inside shard_map. ``local``: (m,) shard of the global array.

    Returns the (n,)-sized buffer per device; device 0's buffer is the sorted
    result, other devices' tails are sentinels (the paper's idle nodes).
    """
    P_ = jax.lax.axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    m = local.shape[-1]
    n = m * P_
    sent = sentinel_for(local.dtype, largest=True)

    # Fig 3 step 2: local "Quicksort"
    local = fast_local_sort(local, ascending=True, impl=local_impl, block_n=block_n)
    buf = jnp.concatenate([local, jnp.full((n - m,), sent, local.dtype)])

    # Fig 3 steps 3–5: binary merge tree
    rounds = P_.bit_length() - 1
    for r in range(rounds):
        d = 1 << r
        perm = [(i, i - d) for i in range(P_) if i % (2 * d) == d]
        received = jax.lax.ppermute(buf, axis_name, perm)  # zeros if not a target
        merged = merge_sorted_pair(buf, received)[..., :n]
        is_receiver = idx % (2 * d) == 0
        buf = jnp.where(is_receiver, merged, buf)
    return buf


def distributed_merge_sort(
    x: jax.Array,
    mesh,
    axis: str,
    *,
    local_impl: str = "xla",
    block_n: int | None = None,
):
    """Sort 1-D ``x`` (length divisible by mesh axis size) across ``mesh[axis]``.

    Returns the sorted array (gathered from device 0's buffer). Memory cost is
    O(n) *per device* — the paper's design; use ``cluster_sort`` for the
    scalable path. ``block_n`` tunes ``local_impl='pallas'``.
    """
    n = x.shape[-1]
    P_ = mesh.shape[axis]
    if n % P_:
        raise ValueError(f"n={n} must divide device count {P_}")

    out = _compiled_merge_tree(mesh, axis, local_impl, block_n)(x)
    # device 0's buffer occupies the first n entries of the (P*n,) output
    return out[:n]


@lru_cache(maxsize=64)
def _compiled_merge_tree(mesh, axis, local_impl, block_n=None):
    """Cache the jitted shard_map so repeated calls don't re-trace."""
    body = partial(
        merge_tree_local, axis_name=axis, local_impl=local_impl, block_n=block_n
    )
    return jax.jit(
        jax.shard_map(body, mesh=mesh, in_specs=P(axis), out_specs=P(axis))
    )
