"""One-step MSD-Radix bucketing (paper §3.4) + beyond-paper splitter selection.

The paper's master node inspects the most significant decimal digit and deals
data into 10 buckets, one (or more) per node; MSD (not LSD) preserves locality
so no inter-node merge is ever needed. Generalizations here:

* ``decimal`` mode — the paper's exact scheme: bucket = MSD of a ``digits``-digit
  decimal key; 10 buckets, nodes limited to 1..10 (kept for fidelity tests).
* ``range`` mode — binary generalization: bucket = top log2(B) bits of the key's
  offset in a static [lo, hi) range; any power-of-two bucket count.
* ``radix`` mode (beyond paper) — ``range`` without the static hints: the
  [lo, hi] endpoints are computed collectively per call
  (``repro.exchange.partition.radix_bucket_ids``), so the mode works on data
  whose range nobody declared and the autotuner can sweep it.
* ``splitters`` mode (beyond paper) — sample-based quantile splitters make the
  buckets balanced under arbitrary key skew (samplesort). The paper's static
  MSD map degrades when keys are non-uniform; DESIGN.md §2.
* ``sample`` mode (beyond paper) — ``splitters`` upgraded to composite
  ``(key, id)`` splitters (``sample_partition_ids``): bucket boundaries can
  land *inside* tie runs, so even all-equal / duplicate-heavy distributions
  balance. ``stable=True`` keeps the kv paths' stable-sort guarantee.

The splitter/radix machinery itself lives in ``repro.exchange.partition``
(the exchange layer's partition policy); ``splitter_bucket`` and
``choose_splitters`` are re-exported here for back-compat. All functions are
shard_map-friendly (pure jnp on local shards; the sampling helpers use
collectives given an axis name).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.exchange.partition import (  # noqa: F401  (re-exported back-compat)
    DEFAULT_OVERSAMPLE,
    choose_splitters,
    radix_bucket_ids,
    sample_partition_ids,
    splitter_bucket,
)

__all__ = [
    "decimal_msd_bucket",
    "range_bucket",
    "splitter_bucket",
    "choose_splitters",
    "make_partitioner",
]


def decimal_msd_bucket(keys: jax.Array, *, digits: int) -> jax.Array:
    """Paper mode: most significant digit of a ``digits``-digit decimal int."""
    scale = 10 ** (digits - 1)
    return jnp.clip(keys // scale, 0, 9).astype(jnp.int32)


def range_bucket(keys: jax.Array, *, n_buckets: int, lo, hi) -> jax.Array:
    """Binary MSD generalization: equal-width buckets over a static [lo, hi)."""
    kf = keys.astype(jnp.float32)
    b = (kf - lo) * (n_buckets / (hi - lo))
    return jnp.clip(b.astype(jnp.int32), 0, n_buckets - 1)


def make_partitioner(
    mode: str,
    *,
    n_buckets: int,
    digits: int = 3,
    lo=0,
    hi=1,
    axis_name: Optional[str] = None,
    oversample: int = 8,
    stable: bool = False,
) -> Callable[[jax.Array], jax.Array]:
    """Return keys -> bucket_ids for the chosen MSD mode.

    ``stable`` only affects ``sample`` mode: it selects arrival-order tie ids
    so a stable kv sort stays stable with bucket boundaries inside tie runs
    (keys-only sorts keep the default interleaved ids, which balance better).
    """
    if mode == "decimal":
        if n_buckets != 10:
            raise ValueError("decimal MSD implies exactly 10 buckets (paper §3.4)")
        return lambda k: decimal_msd_bucket(k, digits=digits)
    if mode == "range":
        return lambda k: range_bucket(k, n_buckets=n_buckets, lo=lo, hi=hi)
    if mode == "radix":
        if axis_name is None:
            raise ValueError("radix mode needs the mesh axis name")
        return lambda k: radix_bucket_ids(k, n_buckets, axis_name)
    if mode == "splitters":
        if axis_name is None:
            raise ValueError("splitters mode needs the mesh axis name")

        def part(k):
            spl = choose_splitters(k, n_buckets, axis_name, oversample=oversample)
            return splitter_bucket(k, spl)

        return part
    if mode == "sample":
        if axis_name is None:
            raise ValueError("sample mode needs the mesh axis name")
        # choose_splitters keeps its historic default; the composite sample
        # partition wants the larger DEFAULT_OVERSAMPLE unless overridden
        os_ = max(oversample, DEFAULT_OVERSAMPLE)
        return lambda k: sample_partition_ids(
            k, n_buckets, axis_name, oversample=os_, stable=stable
        )
    raise ValueError(f"unknown partitioner mode {mode!r}")
