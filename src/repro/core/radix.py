"""One-step MSD-Radix bucketing (paper §3.4) + beyond-paper splitter selection.

The paper's master node inspects the most significant decimal digit and deals
data into 10 buckets, one (or more) per node; MSD (not LSD) preserves locality
so no inter-node merge is ever needed. Generalizations here:

* ``decimal`` mode — the paper's exact scheme: bucket = MSD of a ``digits``-digit
  decimal key; 10 buckets, nodes limited to 1..10 (kept for fidelity tests).
* ``range`` mode — binary generalization: bucket = top log2(B) bits of the key's
  offset in a static [lo, hi) range; any power-of-two bucket count.
* ``splitters`` mode (beyond paper) — sample-based quantile splitters make the
  buckets balanced under arbitrary key skew (samplesort). The paper's static
  MSD map degrades when keys are non-uniform; DESIGN.md §2.

All functions are shard_map-friendly (pure jnp on local shards; the sampling
helper uses collectives given an axis name).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

__all__ = [
    "decimal_msd_bucket",
    "range_bucket",
    "splitter_bucket",
    "choose_splitters",
    "make_partitioner",
]


def decimal_msd_bucket(keys: jax.Array, *, digits: int) -> jax.Array:
    """Paper mode: most significant digit of a ``digits``-digit decimal int."""
    scale = 10 ** (digits - 1)
    return jnp.clip(keys // scale, 0, 9).astype(jnp.int32)


def range_bucket(keys: jax.Array, *, n_buckets: int, lo, hi) -> jax.Array:
    """Binary MSD generalization: equal-width buckets over a static [lo, hi)."""
    kf = keys.astype(jnp.float32)
    b = (kf - lo) * (n_buckets / (hi - lo))
    return jnp.clip(b.astype(jnp.int32), 0, n_buckets - 1)


def splitter_bucket(keys: jax.Array, splitters: jax.Array) -> jax.Array:
    """bucket = rank of key among B-1 sorted splitters (balanced partition)."""
    return jnp.searchsorted(splitters, keys, side="right").astype(jnp.int32)


def choose_splitters(
    local_keys: jax.Array,
    n_buckets: int,
    axis_name: str,
    *,
    oversample: int = 8,
) -> jax.Array:
    """Distributed quantile-splitter selection (samplesort), inside shard_map.

    Every device contributes ``oversample * n_buckets`` strided samples of its
    *sorted* shard; the all-gathered sample is sorted and B-1 quantiles become
    the splitters. One small all_gather — negligible next to the data exchange.
    """
    m = local_keys.shape[-1]
    s = min(m, oversample * n_buckets)
    stride = max(1, m // s)
    local_sorted = jnp.sort(local_keys, axis=-1)
    sample = local_sorted[..., ::stride][..., :s]
    gathered = jax.lax.all_gather(sample, axis_name)  # (P, s)
    flat = jnp.sort(gathered.reshape(-1))
    total = flat.shape[0]
    # B-1 interior quantiles
    q = (jnp.arange(1, n_buckets) * total) // n_buckets
    return flat[q]


def make_partitioner(
    mode: str,
    *,
    n_buckets: int,
    digits: int = 3,
    lo=0,
    hi=1,
    axis_name: Optional[str] = None,
    oversample: int = 8,
) -> Callable[[jax.Array], jax.Array]:
    """Return keys -> bucket_ids for the chosen MSD mode."""
    if mode == "decimal":
        if n_buckets != 10:
            raise ValueError("decimal MSD implies exactly 10 buckets (paper §3.4)")
        return lambda k: decimal_msd_bucket(k, digits=digits)
    if mode == "range":
        return lambda k: range_bucket(k, n_buckets=n_buckets, lo=lo, hi=hi)
    if mode == "splitters":
        if axis_name is None:
            raise ValueError("splitters mode needs the mesh axis name")

        def part(k):
            spl = choose_splitters(k, n_buckets, axis_name, oversample=oversample)
            return splitter_bucket(k, spl)

        return part
    raise ValueError(f"unknown partitioner mode {mode!r}")
