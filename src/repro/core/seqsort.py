"""Sequential sort models from paper Fig 1, in TPU-expressible form.

The paper compares three sequential sorts (Fig 5) and picks Quicksort as the
per-worker sort. On a vector machine the roles map as:

* Fig 1(a) recursive Merge sort      -> host-side reference (numpy), used only
  by the Fig-5 benchmark as the paper's slow baseline. Recursion is not
  jax-traceable and is precisely what the paper itself moves away from.
* Fig 1(b) non-recursive Merge sort  -> ``nonrecursive_merge_sort``: bottom-up
  width-doubling rounds of vectorized stable rank-merges. Fixed schedule,
  jit-compatible — this *is* a TPU-idiomatic algorithm as published.
* Fig 1(c) recursive Quicksort       -> ``fast_local_sort``: the role "fastest
  available sequential sort" is played by XLA's variadic sort on CPU/TPU and
  by the Pallas bitonic kernel inside kernels/. (DESIGN.md §7: the hybrid
  structure, not quicksort's recursion, is the paper's transferable insight.)
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .bitonic import bitonic_sort
from .merge import merge_adjacent

__all__ = [
    "recursive_merge_sort_host",
    "nonrecursive_merge_sort",
    "pallas_local_sort",
    "fast_local_sort",
    "LOCAL_SORTS",
]


def recursive_merge_sort_host(x: np.ndarray) -> np.ndarray:
    """Paper Fig 1(a), host-side reference implementation (numpy, recursive)."""
    x = np.asarray(x)
    if x.shape[-1] <= 2:
        return np.sort(x, axis=-1, kind="stable")
    mid = x.shape[-1] // 2
    left = recursive_merge_sort_host(x[..., :mid])
    right = recursive_merge_sort_host(x[..., mid:])
    out = np.empty_like(x)
    # vectorized two-list merge via ranks (same identity as merge.py)
    la = left.shape[-1]
    pos_a = np.arange(la) + _np_searchsorted(right, left, side="left")
    pos_b = np.arange(right.shape[-1]) + _np_searchsorted(left, right, side="right")
    np.put_along_axis(out, pos_a, left, axis=-1)
    np.put_along_axis(out, pos_b, right, axis=-1)
    return out


def _np_searchsorted(sorted_arr, query, side):
    flat_s = sorted_arr.reshape(-1, sorted_arr.shape[-1])
    flat_q = query.reshape(-1, query.shape[-1])
    out = np.stack(
        [np.searchsorted(s, q, side=side) for s, q in zip(flat_s, flat_q)]
    )
    return out.reshape(query.shape)


@partial(jax.jit, static_argnames=("ascending",))
def nonrecursive_merge_sort(x: jax.Array, *, ascending: bool = True) -> jax.Array:
    """Paper Fig 1(b): bottom-up merge sort, each round fully vectorized.

    Pads to a power of two with sentinels; log2(n) rounds of ``merge_adjacent``.
    Stable (rank merge breaks ties left-first).
    """
    from .bitonic import next_pow2, sentinel_for

    n = x.shape[-1]
    np2 = next_pow2(n)
    if np2 != n:
        pad = [(0, 0)] * (x.ndim - 1) + [(0, np2 - n)]
        x = jnp.pad(x, pad, constant_values=sentinel_for(x.dtype, largest=True))
    width = 1
    while width < np2:
        x = merge_adjacent(x, width)
        width *= 2
    x = x[..., :n]
    return x if ascending else jnp.flip(x, axis=-1)


def pallas_local_sort(
    x: jax.Array, *, ascending: bool = True, block_n: int | None = None
) -> jax.Array:
    """Shape-safe wrapper over the Pallas VMEM bitonic kernel.

    Accepts any last-axis length >= 1 and arbitrary leading batch dims:
    non-pow2 lengths are padded with +sentinel keys (``pallas_sort`` does the
    pad/slice), batches run via ``vmap`` over a flattened leading axis, and
    descending order flips the valid prefix after the ascending kernel so
    pad sentinels never leak to the front.  Off-TPU the kernels execute in
    interpret mode (``pallas_sort``'s auto-detection), so the same code path
    is testable on CPU and fast on real TPUs.
    """
    from repro.kernels.bitonic_sort.ops import (
        DEFAULT_BLOCK_N,
        pallas_sort,
        vmap_last_axis,
    )

    bn = DEFAULT_BLOCK_N if block_n is None else block_n
    out = vmap_last_axis(partial(pallas_sort, block_n=bn), x)
    return out if ascending else jnp.flip(out, axis=-1)


def fast_local_sort(
    x: jax.Array,
    *,
    ascending: bool = True,
    impl: str = "xla",
    block_n: int | None = None,
) -> jax.Array:
    """The "sequential Quicksort" role: fastest single-worker sort available.

    impl='xla'     -> XLA variadic sort (the platform's tuned local sort)
    impl='bitonic' -> our branch-free network, pure-jnp form
    impl='pallas'  -> the same network as a VMEM-tiled Pallas kernel
                      (``block_n`` tunes the tile width; interpret mode off-TPU)
    impl='merge'   -> paper Fig 1(b) non-recursive merge sort

    NaN keys: only 'xla' totally orders NaN; the network impls ('bitonic',
    'pallas') leave output unspecified for NaN — reject NaN upstream
    (SortService does) or use 'xla'.
    """
    if impl == "xla":
        out = jnp.sort(x, axis=-1)
        return out if ascending else jnp.flip(out, axis=-1)
    if impl == "bitonic":
        return bitonic_sort(x, ascending=ascending)
    if impl == "pallas":
        return pallas_local_sort(x, ascending=ascending, block_n=block_n)
    if impl == "merge":
        return nonrecursive_merge_sort(x, ascending=ascending)
    raise ValueError(f"unknown local sort impl {impl!r}")


LOCAL_SORTS = ("xla", "bitonic", "pallas", "merge")
