"""Paper models A & B: shared-memory parallel sort (OpenMP -> single-chip SPMD).

The OpenMP "threads" of Fig 2 become T independent tiles of one device's
array. Phase 1 sorts every tile in parallel (vmapped local sort / Pallas
kernel); phase 2 runs the paper's binary merge tree — log2(T) rounds where
round r merges adjacent sorted runs of width n/T * 2^r. On a vector machine
all surviving "threads" of a round execute as one vectorized ``merge_adjacent``
call, so the paper's idling of half the threads per round costs nothing here —
but the *schedule* (width-doubling pairwise merges) is exactly Fig 2.

Model A: local sort = non-recursive merge sort     (paper 3.2 first variant)
Model B: local sort = "quicksort" role (XLA sort / bitonic) — the hybrid that
         wins in the paper (Fig 6) and that we default to everywhere.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .bitonic import next_pow2, sentinel_for
from .merge import merge_adjacent
from .seqsort import fast_local_sort

__all__ = ["shared_memory_sort"]


@partial(jax.jit, static_argnames=("n_threads", "local_impl", "ascending", "block_n"))
def shared_memory_sort(
    x: jax.Array,
    *,
    n_threads: int = 8,
    local_impl: str = "xla",
    ascending: bool = True,
    block_n: int | None = None,
) -> jax.Array:
    """Sort the last axis with the paper's shared-memory algorithm.

    n_threads must be a power of two (paper: "works with a power of two number
    of threads"). Arbitrary n is handled by sentinel padding. ``block_n`` is
    the VMEM tile width for ``local_impl='pallas'`` (ignored otherwise).
    """
    if n_threads & (n_threads - 1) or n_threads < 1:
        raise ValueError("n_threads must be a power of two (paper §3.2)")
    *lead, n = x.shape
    np2 = max(next_pow2(n), n_threads)
    if np2 != n:
        # pad with +sentinel; ascending internal sort keeps pads at the end
        pad = [(0, 0)] * (x.ndim - 1) + [(0, np2 - n)]
        x = jnp.pad(x, pad, constant_values=sentinel_for(x.dtype, largest=True))
    tile = np2 // n_threads

    # Phase 1 — every "thread" sorts its tile (Fig 2 step: call sorting function)
    tiles = x.reshape(*lead, n_threads, tile)
    tiles = fast_local_sort(tiles, ascending=True, impl=local_impl, block_n=block_n)
    x = tiles.reshape(*lead, np2)

    # Phase 2 — binary merge tree (Fig 2 steps a–d), one round per doubling
    width = tile
    while width < np2:
        x = merge_adjacent(x, width)
        width *= 2
    x = x[..., :n]
    return x if ascending else jnp.flip(x, axis=-1)
