"""Vectorized merge of sorted runs — the paper's "merge & sort function".

Two realizations of "merge two sorted lists of length w":

* ``rank_merge_pairs`` — merge-path/rank based: the output position of every
  element is its own index plus its rank in the other list (``searchsorted``),
  then a scatter. O(n log w) work, one gatherless scatter; this is the
  TPU-friendly analogue of the paper's sequential two-pointer merge.
  ``searchsorted`` sides are chosen so the merge is *stable* (left-run elements
  precede equal right-run elements), matching merge sort's defining property.

* ``bitonic`` merge (see ``bitonic.py``) — branch-free compare-exchange network;
  used inside the Pallas kernel where scatters are awkward.

``merge_adjacent`` performs one round of the paper's bottom-up merge: an array
viewed as ``r`` sorted runs of width ``w`` becomes ``r/2`` sorted runs of width
``2w``. Repeating it is exactly Fig 1(b)'s non-recursive merge sort and the
"All Threads" merge loop of Fig 2/Fig 3.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

__all__ = ["rank_merge_pairs", "merge_adjacent", "merge_sorted_pair"]


@partial(jax.jit, static_argnames=("has_values",))
def _rank_merge(pairs, values, *, has_values: bool):
    """pairs: (..., 2, w) two sorted runs -> (..., 2w) merged, stable."""
    a = pairs[..., 0, :]
    b = pairs[..., 1, :]
    w = a.shape[-1]
    # rank of a[i] among b (left side: a wins ties -> stable) and vice versa
    pos_a = jnp.arange(w) + _searchsorted(b, a, side="left")
    pos_b = jnp.arange(w) + _searchsorted(a, b, side="right")
    inv = _invert_perm(jnp.concatenate([pos_a, pos_b], axis=-1))
    out = jnp.take_along_axis(  # scatter via inverse permutation
        jnp.concatenate([a, b], axis=-1), inv, axis=-1
    )
    if not has_values:
        return out, None
    merged_vals = jax.tree.map(
        lambda v: jnp.take_along_axis(
            jnp.concatenate([v[..., 0, :], v[..., 1, :]], axis=-1), inv, axis=-1
        ),
        values,
    )
    return out, merged_vals


def _searchsorted(sorted_arr, query, *, side):
    """Batched searchsorted along the last axis (vmapped over leading dims)."""
    flat_s = sorted_arr.reshape(-1, sorted_arr.shape[-1])
    flat_q = query.reshape(-1, query.shape[-1])
    out = jax.vmap(lambda s, q: jnp.searchsorted(s, q, side=side))(flat_s, flat_q)
    return out.reshape(query.shape)


def _invert_perm(perm):
    """Invert a permutation given along the last axis."""
    iota = jnp.broadcast_to(jnp.arange(perm.shape[-1], dtype=perm.dtype), perm.shape)
    flat_p = perm.reshape(-1, perm.shape[-1])
    flat_i = iota.reshape(-1, iota.shape[-1])

    def one(p, i):
        return jnp.zeros_like(p).at[p].set(i)

    return jax.vmap(one)(flat_p, flat_i).reshape(perm.shape)


def rank_merge_pairs(pairs, values=None):
    """Merge (..., 2, w) sorted-run pairs into (..., 2w) stably."""
    out, vals = _rank_merge(pairs, values, has_values=values is not None)
    return out if values is None else (out, vals)


def merge_sorted_pair(a, b, va=None, vb=None):
    """Stable merge of two sorted arrays along the last axis (equal length)."""
    pairs = jnp.stack([a, b], axis=-2)
    if va is None:
        return rank_merge_pairs(pairs)
    values = jax.tree.map(lambda x, y: jnp.stack([x, y], axis=-2), va, vb)
    return rank_merge_pairs(pairs, values)


def merge_adjacent(x, width: int, values=None):
    """One bottom-up merge round: sorted runs of ``width`` -> runs of ``2*width``.

    ``x``: (..., n) with n % (2*width) == 0 and each aligned ``width`` slice
    already sorted. Vectorizes the paper's per-round pairwise merges across all
    run pairs at once (all "threads" of a round in one shot).
    """
    *lead, n = x.shape
    assert n % (2 * width) == 0, (n, width)
    pairs = x.reshape(*lead, n // (2 * width), 2, width)
    if values is None:
        merged = rank_merge_pairs(pairs)
        return merged.reshape(*lead, n)
    vals = jax.tree.map(lambda v: v.reshape(*lead, n // (2 * width), 2, width), values)
    merged, mvals = rank_merge_pairs(pairs, vals)
    return merged.reshape(*lead, n), jax.tree.map(
        lambda v: v.reshape(*lead, n), mvals
    )
