"""Paper model D: Hybrid-memory sort — one-step MSD-Radix scatter, local sort.

This is the paper's headline algorithm and the framework's production path:

  1. every device computes each key's destination from its most significant
     digit/bits (or sample splitters) — ``radix.py``;
  2. one ``all_to_all`` ships every key to its destination shard — after this
     step key ranges are disjoint, so **no inter-device merging ever happens**
     (the paper's "eliminate all internal data transfers" insight);
  3. each device sorts what it received with the fast local sort (the paper's
     per-node OpenMP hybrid = our vmapped XLA/bitonic sort).

The exchange machinery itself — ``partition_exchange``/``combine_exchange``,
``slab_geometry``, the capacity-retry driver — lives in ``repro.exchange``
(the unified adaptive exchange layer, docs/exchange.md); this module is the
*sort* consumer of that layer, MoE dispatch (``models/moe.py``) is the other.
The names are re-exported here for back-compat with pre-extraction callers.
"""
from __future__ import annotations

from functools import lru_cache, partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.exchange import (  # noqa: F401  (re-exported for back-compat)
    ExchangeResult,
    combine_exchange,
    partition_exchange,
    partition_of,
    run_with_capacity_retries,
    slab_geometry,
    slab_valid,
)

from .radix import make_partitioner
from .seqsort import fast_local_sort

__all__ = [
    "ExchangeResult",
    "partition_exchange",
    "combine_exchange",
    "cluster_sort_local",
    "cluster_sort",
    "slab_geometry",
]


def cluster_sort_local(
    local: jax.Array,
    axis_name: str,
    *,
    capacity: int,
    partitioner: Callable[[jax.Array], jax.Array],
    n_buckets: int,
    local_impl: str = "xla",
    block_n: Optional[int] = None,
):
    """shard_map body for model D. local: (m,) shard. Returns
    (sorted_slab (B/P*C per shard,), my_count, peak, overflow): entries
    [0, my_count) of the slab are this shard's contiguous range of the
    globally sorted output; ``peak`` is the mesh-wide max per-(sender,
    bucket) element count — the exchange-telemetry signal capacity learning
    feeds on (repro.engine.adapt). ``n_buckets`` must be a multiple of the
    axis size; the contiguous bucket -> shard map keeps global order
    (DESIGN.md §2)."""
    P_ = jax.lax.axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    bucket = partitioner(local).astype(jnp.int32)
    ex = partition_exchange(
        local, None, bucket, axis_name, capacity=capacity, n_buckets=n_buckets
    )
    flat = ex.recv_keys.reshape(-1)
    sorted_slab = fast_local_sort(flat, ascending=True, impl=local_impl, block_n=block_n)
    global_counts = jax.lax.psum(ex.counts, axis_name)  # (n_buckets,)
    owner = (jnp.arange(n_buckets, dtype=jnp.int32) * P_) // n_buckets
    my_count = jnp.sum(jnp.where(owner == idx, global_counts, 0)).astype(jnp.int32)
    peak = jax.lax.pmax(jnp.max(ex.counts), axis_name)
    return sorted_slab, my_count[None], peak, ex.overflow


@lru_cache(maxsize=256)
def _compiled_cluster_sort(
    mesh, axis, mode, capacity, part_buckets, n_buckets, digits, lo, hi, local_impl,
    block_n=None,
):
    """One jitted shard_map per static config — repeated cluster_sort calls
    (serving traffic, autotune reps) reuse the traced executable instead of
    rebuilding fresh closures every call."""
    part = make_partitioner(
        mode, n_buckets=part_buckets, digits=digits, lo=lo, hi=hi, axis_name=axis
    )
    body = partial(
        cluster_sort_local,
        axis_name=axis,
        capacity=capacity,
        partitioner=part,
        n_buckets=n_buckets,
        local_impl=local_impl,
        block_n=block_n,
    )
    return jax.jit(
        jax.shard_map(
            body, mesh=mesh, in_specs=P(axis), out_specs=(P(axis), P(axis), P(), P())
        )
    )


def cluster_sort(
    x: jax.Array,
    mesh,
    axis: str,
    *,
    mode: str = "splitters",
    capacity_factor: float = 2.0,
    digits: int = 3,
    lo=0,
    hi=1,
    local_impl: str = "xla",
    block_n: Optional[int] = None,
    max_retries: int = 4,
    telemetry: Optional[Callable[..., None]] = None,
):
    """Sort 1-D ``x`` across ``mesh[axis]`` with the paper's cluster algorithm.

    Returns (sorted_x, valid) where ``sorted_x`` is (P*C_total,) with shard p's
    contiguous range in slots [p*C_total + 0, p*C_total + counts[p]); ``valid``
    masks real entries. Retries with doubled capacity on overflow (the
    fault-tolerant wrapper promised in DESIGN.md §2). ``block_n`` tunes
    ``local_impl='pallas'``.

    ``telemetry`` is an optional callback invoked once per call (including a
    failing one) with keyword args ``m``, ``part_buckets``, ``capacity``
    (final attempt), ``peak`` (max per-(sender, bucket) count observed),
    ``overflowed``, ``retries``, ``recompiles`` (fresh executables the
    capacity-doubling retries forced — a first-call warmup compile doesn't
    count), and ``partition`` (the mode's family, ``"radix"``/``"sample"``)
    — the feedback ``repro.engine.adapt`` turns into learned capacity
    factors and, for persistently skewed radix keys, sample-mode promotion.
    """
    P_ = mesh.shape[axis]
    n = x.shape[-1]
    if n % P_:
        raise ValueError(f"n={n} must divide axis size {P_}")
    m = n // P_
    part_buckets, n_buckets, cap = slab_geometry(mode, m, P_, capacity_factor)

    (slab,), counts = run_with_capacity_retries(
        lambda c: _compiled_cluster_sort(
            mesh, axis, mode, c, part_buckets, n_buckets, digits, lo, hi,
            local_impl, block_n,
        ),
        lambda fn: fn(x),
        m=m,
        part_buckets=part_buckets,
        cap=cap,
        max_retries=max_retries,
        telemetry=telemetry,
        lru=_compiled_cluster_sort,
        label="cluster_sort",
        partition=partition_of(mode),
    )
    return slab, slab_valid(slab.shape[0], counts, P_)
