"""Paper model D: Hybrid-memory sort — one-step MSD-Radix scatter, local sort.

This is the paper's headline algorithm and the framework's production path:

  1. every device computes each key's destination from its most significant
     digit/bits (or sample splitters) — ``radix.py``;
  2. one ``all_to_all`` ships every key to its destination shard — after this
     step key ranges are disjoint, so **no inter-device merging ever happens**
     (the paper's "eliminate all internal data transfers" insight);
  3. each device sorts what it received with the fast local sort (the paper's
     per-node OpenMP hybrid = our vmapped XLA/bitonic sort).

SPMD adaptation (DESIGN.md §2): MPI's variable-length messages become
fixed-capacity slabs of ``capacity`` keys per (src, dst) pair, padded with
sentinels. Overflow is detected collectively and surfaced; the non-jit
``cluster_sort`` wrapper doubles capacity and retries, and
``capacity == m`` is a loss-free guarantee.

``partition_exchange`` / ``combine_exchange`` are the generic primitives —
MoE dispatch (models/moe.py) is literally these two calls around the expert
FFN, which is why this paper integrates as a first-class feature of the
framework.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from functools import lru_cache, partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .bitonic import sentinel_for
from .radix import make_partitioner
from .seqsort import fast_local_sort

__all__ = [
    "ExchangeResult",
    "partition_exchange",
    "combine_exchange",
    "cluster_sort_local",
    "cluster_sort",
    "slab_geometry",
]


@dataclass
class ExchangeResult:
    recv_keys: jax.Array        # (P, C) keys received, sentinel-padded
    recv_values: Any            # pytree of (P, C, ...) or None
    recv_src_slot: jax.Array    # (P, C) flat slot id in the *sender's* slab
    send_slot: jax.Array        # (m,) my element's slab slot, -1 if dropped
    counts: jax.Array           # (P,) how many of my elements target each shard
    overflow: jax.Array         # scalar bool: any (src,dst) bucket overflowed


def _stable_argsort_by(dest: jax.Array) -> jax.Array:
    """Stable order grouping elements by destination (XLA sort = local 'quicksort')."""
    return jnp.argsort(dest, stable=True)


def _quantize_rows(v: jax.Array):
    """bf16/f32 (N, ...) -> (int8 payload, f32 per-row scale) for the wire."""
    vf = v.astype(jnp.float32)
    flat = vf.reshape(v.shape[0], -1)
    scale = jnp.max(jnp.abs(flat), axis=-1) / 127.0
    q = jnp.round(vf / jnp.maximum(scale, 1e-12).reshape((-1,) + (1,) * (v.ndim - 1)))
    return q.astype(jnp.int8), scale


def _dequantize_rows(q: jax.Array, scale: jax.Array, dtype):
    return (
        q.astype(jnp.float32) * scale.reshape((-1,) + (1,) * (q.ndim - 1))
    ).astype(dtype)


def _compressed_a2a(axis_name: str, P_: int, row: int):
    """int8-on-the-wire all_to_all with a straight-through backward.

    Forward ships (int8 payload, f32 per-row scale) — ~0.53x the bf16 bytes.
    ``round`` has zero gradient, so the custom VJP routes cotangents through
    the (self-transpose) all_to_all uncompressed.
    """
    a2a = partial(
        jax.lax.all_to_all, axis_name=axis_name, split_axis=0, concat_axis=0, tiled=False
    )

    @jax.custom_vjp
    def qa2a(v):  # v: (P_*row, ...) flat slab
        q, s = _quantize_rows(v)
        rq = a2a(q.reshape((P_, row) + v.shape[1:]))
        rs = a2a(s.reshape(P_, row))
        return _dequantize_rows(
            rq.reshape((P_ * row,) + v.shape[1:]), rs.reshape(-1), v.dtype
        )

    def fwd(v):
        return qa2a(v), None

    def bwd(_, g):
        back = a2a(g.reshape((P_, row) + g.shape[1:]))
        return (back.reshape((P_ * row,) + g.shape[1:]),)

    qa2a.defvjp(fwd, bwd)
    return qa2a


def partition_exchange(
    keys: jax.Array,
    values: Any,
    bucket_ids: jax.Array,
    axis_name: str,
    *,
    capacity: int,
    n_buckets: Optional[int] = None,
    compress: bool = False,
) -> ExchangeResult:
    """Ship every element to the shard owning its bucket (call inside shard_map).

    keys: (m,); values: pytree of (m, ...) moved alongside; bucket_ids: (m,)
    int32 in [0, n_buckets). ``n_buckets`` defaults to the axis size P and must
    be a multiple of it; buckets map to shards contiguously (shard =
    bucket * P // n_buckets) so bucket order == shard order (global sortedness
    / expert grouping both rely on this). ``capacity`` is per (sender, bucket).

    ``compress=True`` ships *float* value payloads as int8 with a per-element
    f32 scale (beyond-paper: ~0.53x wire bytes for bf16 tokens; quantization
    is straight-through for autodiff — the dequantized values carry
    gradients). Integer leaves always travel uncompressed: quantization is
    lossy and would corrupt indices/ids.

    Returns slabs of shape (P, B_loc * capacity): row j = what shard j sent me,
    laid out as (B_loc, capacity) for my local buckets.
    """
    P_ = jax.lax.axis_size(axis_name)
    m = keys.shape[-1]
    C = capacity
    B = P_ if n_buckets is None else n_buckets
    if B % P_:
        raise ValueError(f"n_buckets={B} must be a multiple of axis size {P_}")
    sent = sentinel_for(keys.dtype, largest=True)

    # --- group by bucket (stable: preserves arrival order per bucket) ---
    order = _stable_argsort_by(bucket_ids)
    sorted_bkt = bucket_ids[order]
    counts = jnp.bincount(bucket_ids, length=B).astype(jnp.int32)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    pos_in_bucket = jnp.arange(m, dtype=jnp.int32) - offsets[sorted_bkt]
    valid = pos_in_bucket < C
    slot_sorted = jnp.where(valid, sorted_bkt * C + pos_in_bucket, B * C)

    # --- build fixed-capacity send slab (scatter, OOB slots dropped) ---
    slab_keys = jnp.full((B * C,), sent, keys.dtype)
    slab_keys = slab_keys.at[slot_sorted].set(keys[order], mode="drop")

    def to_slab(v):
        buf = jnp.zeros((B * C,) + v.shape[1:], v.dtype)
        return buf.at[slot_sorted].set(v[order], mode="drop")

    slab_values = None if values is None else jax.tree.map(to_slab, values)

    # remember where each *original* element went (for combine_exchange)
    send_slot = (
        jnp.full((m,), -1, jnp.int32)
        .at[order]
        .set(jnp.where(valid, slot_sorted, -1).astype(jnp.int32))
    )
    # receiver-side validity mask rides along as slot ids (-1 = padding)
    slab_src_slot = (
        jnp.full((B * C,), -1, jnp.int32)
        .at[slot_sorted]
        .set(slot_sorted.astype(jnp.int32), mode="drop")
    )

    # --- the one MSD-radix all_to_all (paper Fig 4 arrow: master -> nodes) ---
    row = (B // P_) * C
    a2a = partial(
        jax.lax.all_to_all, axis_name=axis_name, split_axis=0, concat_axis=0, tiled=False
    )
    recv_keys = a2a(slab_keys.reshape(P_, row))
    recv_src_slot = a2a(slab_src_slot.reshape(P_, row))
    if values is None:
        recv_values = None
    elif compress:
        # int8 quantization is lossy and only meaningful for float payloads;
        # integer leaves (indices, ids) ship uncompressed to stay exact
        recv_values = jax.tree.map(
            lambda v: (
                _compressed_a2a(axis_name, P_, row)(v).reshape((P_, row) + v.shape[1:])
                if jnp.issubdtype(v.dtype, jnp.floating)
                else a2a(v.reshape((P_, row) + v.shape[1:]))
            ),
            slab_values,
        )
    else:
        recv_values = jax.tree.map(
            lambda v: a2a(v.reshape((P_, row) + v.shape[1:])), slab_values
        )

    overflow = jax.lax.pmax(jnp.max(counts) > C, axis_name)
    return ExchangeResult(
        recv_keys=recv_keys,
        recv_values=recv_values,
        recv_src_slot=recv_src_slot,
        send_slot=send_slot,
        counts=counts,
        overflow=overflow,
    )


def combine_exchange(
    processed: Any,
    ex: ExchangeResult,
    axis_name: str,
    *,
    fill=0,
) -> Any:
    """Inverse exchange: return processed (P, C, ...) slabs to their senders and
    restore original element order. Dropped (overflowed) elements get ``fill``.
    """
    a2a = partial(
        jax.lax.all_to_all, axis_name=axis_name, split_axis=0, concat_axis=0, tiled=False
    )
    returned = jax.tree.map(a2a, processed)  # (P, C, ...) back in sender layout

    m = ex.send_slot.shape[0]

    def gather(v):
        flat = v.reshape((v.shape[0] * v.shape[1],) + v.shape[2:])
        safe = jnp.clip(ex.send_slot, 0, flat.shape[0] - 1)
        out = flat[safe]
        mask = (ex.send_slot >= 0).reshape((m,) + (1,) * (out.ndim - 1))
        return jnp.where(mask, out, jnp.asarray(fill, out.dtype))

    return jax.tree.map(gather, returned)


def slab_geometry(mode: str, m: int, P_: int, capacity_factor: float):
    """Exchange geometry for model D: (part_buckets, n_buckets, capacity).

    ``part_buckets`` is what the partitioner emits (10 in the paper's decimal
    mode, P otherwise); ``n_buckets`` rounds it up to the nearest multiple of
    P so ``partition_exchange``'s ``B % P == 0`` contract holds for any node
    count (buckets 10..n_buckets-1 simply stay empty).  ``capacity`` is sized
    per *bucket* — a uniform load puts ~m/part_buckets keys in each (sender,
    bucket) pair, so deriving it from P (the old behaviour) under-provisioned
    exactly when buckets outnumber shards.
    """
    part_buckets = 10 if mode == "decimal" else P_
    n_buckets = -(-part_buckets // P_) * P_
    cap = min(m, max(1, -(-int(capacity_factor * m) // part_buckets)))
    return part_buckets, n_buckets, cap


# serializes the (miss-count snapshot, memoized construction) pairs inside
# run_with_capacity_retries so concurrent callers never attribute each
# other's cache misses to their own telemetry; construction is cheap (the
# jit wrapper — actual compilation happens at call time, outside the lock)
_RECOMPILE_COUNT_LOCK = threading.Lock()


def run_with_capacity_retries(
    make_fn: Callable[[int], Callable],
    run_fn: Callable[[Callable], tuple],
    *,
    m: int,
    P_: int,
    part_buckets: int,
    cap: int,
    max_retries: int,
    telemetry: Optional[Callable[..., None]],
    lru,
    label: str,
):
    """Shared capacity-doubling retry driver for the cluster sorts.

    ``make_fn(cap)`` returns the compiled shard_map for one capacity (an
    ``lru_cache``-memoized factory — ``lru`` is that factory, used to count
    retry-forced fresh compilations); ``run_fn(fn)`` executes it and returns
    ``(*outputs, counts, peak, overflow)``.  On success returns
    ``(outputs, valid)`` where ``valid`` masks the real slab entries; on
    persistent overflow raises ``RuntimeError``.  Either way the final
    attempt's telemetry (peak per-(sender, bucket) count, overflow/retry/
    recompile events) is reported through ``telemetry`` — the feedback
    ``repro.engine.adapt`` turns into learned capacity factors.
    """
    retries, peak, recompiles = 0, 0, 0

    def report(overflowed: bool) -> None:
        if telemetry is not None:
            telemetry(
                m=m,
                part_buckets=part_buckets,
                capacity=cap,
                peak=peak,
                overflowed=overflowed,
                retries=retries,
                recompiles=recompiles,
            )

    for attempt in range(max_retries + 1):
        if attempt:
            cap = min(m, cap * 2)
        with _RECOMPILE_COUNT_LOCK:
            misses0 = lru.cache_info().misses
            fn = make_fn(cap)
            fresh = lru.cache_info().misses - misses0
        if attempt:
            # only retry attempts count: a first-call warmup compile is the
            # normal cost of a new config, not an overflow-forced recompile
            recompiles += fresh
        *outs, counts, att_peak, overflow = run_fn(fn)
        peak = max(peak, int(att_peak))
        retries = attempt
        if not bool(overflow):
            report(overflowed=attempt > 0)
            C_total = outs[0].shape[0] // P_
            pos = jnp.arange(outs[0].shape[0]) % C_total
            valid = pos < jnp.repeat(counts, C_total)
            return outs, valid
        if cap >= m:
            break  # already loss-free capacity; more retries can't help
    report(overflowed=True)
    raise RuntimeError(f"{label}: capacity overflow persisted after retries")


def cluster_sort_local(
    local: jax.Array,
    axis_name: str,
    *,
    capacity: int,
    partitioner: Callable[[jax.Array], jax.Array],
    n_buckets: int,
    local_impl: str = "xla",
    block_n: Optional[int] = None,
):
    """shard_map body for model D. local: (m,) shard. Returns
    (sorted_slab (B/P*C per shard,), my_count, peak, overflow): entries
    [0, my_count) of the slab are this shard's contiguous range of the
    globally sorted output; ``peak`` is the mesh-wide max per-(sender,
    bucket) element count — the exchange-telemetry signal capacity learning
    feeds on (repro.engine.adapt). ``n_buckets`` must be a multiple of the
    axis size; the contiguous bucket -> shard map keeps global order
    (DESIGN.md §2)."""
    P_ = jax.lax.axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    bucket = partitioner(local).astype(jnp.int32)
    ex = partition_exchange(
        local, None, bucket, axis_name, capacity=capacity, n_buckets=n_buckets
    )
    flat = ex.recv_keys.reshape(-1)
    sorted_slab = fast_local_sort(flat, ascending=True, impl=local_impl, block_n=block_n)
    global_counts = jax.lax.psum(ex.counts, axis_name)  # (n_buckets,)
    owner = (jnp.arange(n_buckets, dtype=jnp.int32) * P_) // n_buckets
    my_count = jnp.sum(jnp.where(owner == idx, global_counts, 0)).astype(jnp.int32)
    peak = jax.lax.pmax(jnp.max(ex.counts), axis_name)
    return sorted_slab, my_count[None], peak, ex.overflow


@lru_cache(maxsize=256)
def _compiled_cluster_sort(
    mesh, axis, mode, capacity, part_buckets, n_buckets, digits, lo, hi, local_impl,
    block_n=None,
):
    """One jitted shard_map per static config — repeated cluster_sort calls
    (serving traffic, autotune reps) reuse the traced executable instead of
    rebuilding fresh closures every call."""
    part = make_partitioner(
        mode, n_buckets=part_buckets, digits=digits, lo=lo, hi=hi, axis_name=axis
    )
    body = partial(
        cluster_sort_local,
        axis_name=axis,
        capacity=capacity,
        partitioner=part,
        n_buckets=n_buckets,
        local_impl=local_impl,
        block_n=block_n,
    )
    return jax.jit(
        jax.shard_map(
            body, mesh=mesh, in_specs=P(axis), out_specs=(P(axis), P(axis), P(), P())
        )
    )


def cluster_sort(
    x: jax.Array,
    mesh,
    axis: str,
    *,
    mode: str = "splitters",
    capacity_factor: float = 2.0,
    digits: int = 3,
    lo=0,
    hi=1,
    local_impl: str = "xla",
    block_n: Optional[int] = None,
    max_retries: int = 4,
    telemetry: Optional[Callable[..., None]] = None,
):
    """Sort 1-D ``x`` across ``mesh[axis]`` with the paper's cluster algorithm.

    Returns (sorted_x, valid) where ``sorted_x`` is (P*C_total,) with shard p's
    contiguous range in slots [p*C_total + 0, p*C_total + counts[p]); ``valid``
    masks real entries. Retries with doubled capacity on overflow (the
    fault-tolerant wrapper promised in DESIGN.md §2). ``block_n`` tunes
    ``local_impl='pallas'``.

    ``telemetry`` is an optional callback invoked once per call (including a
    failing one) with keyword args ``m``, ``part_buckets``, ``capacity``
    (final attempt), ``peak`` (max per-(sender, bucket) count observed),
    ``overflowed``, ``retries``, and ``recompiles`` (fresh executables the
    capacity-doubling retries forced — a first-call warmup compile doesn't
    count) — the feedback ``repro.engine.adapt`` turns into learned
    capacity factors.
    """
    P_ = mesh.shape[axis]
    n = x.shape[-1]
    if n % P_:
        raise ValueError(f"n={n} must divide axis size {P_}")
    m = n // P_
    part_buckets, n_buckets, cap = slab_geometry(mode, m, P_, capacity_factor)

    (slab,), valid = run_with_capacity_retries(
        lambda c: _compiled_cluster_sort(
            mesh, axis, mode, c, part_buckets, n_buckets, digits, lo, hi,
            local_impl, block_n,
        ),
        lambda fn: fn(x),
        m=m,
        P_=P_,
        part_buckets=part_buckets,
        cap=cap,
        max_retries=max_retries,
        telemetry=telemetry,
        lru=_compiled_cluster_sort,
        label="cluster_sort",
    )
    return slab, valid
