"""Vectorized bitonic sorting network — the TPU-native local sort.

The paper's per-worker "fast sequential sort" is recursive Quicksort. Quicksort's
data-dependent recursion has no TPU analogue; the fixed-schedule equivalent of a
fast local sort on a vector machine is the bitonic network: every stage is a
branch-free compare-exchange expressible as ``where(min/max)`` over a reshaped
axis. This file is the pure-jnp form; ``repro/kernels/bitonic_sort`` is the
Pallas VMEM-tiled version of the same network and must match it element-for-
element.

All entry points operate on the last axis and accept arbitrary leading batch
dims. Lengths are padded to the next power of two with sentinels.

Stability: a bitonic network is unstable; the paper chose merge sort for its
stability. We restore it with a lexicographic (key, original-rank) comparator —
rank ties never exist, so the network output is the unique stable order.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "bitonic_sort",
    "bitonic_merge_pair",
    "bitonic_topk",
    "next_pow2",
    "sentinel_for",
]


# canonical home is the exchange layer (slab padding shares the sort
# sentinel); re-exported here for the core-layer callers that grew up with it
from repro.exchange import sentinel_for  # noqa: E402, F401


def next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


def _split(x, j: int):
    """(..., n) -> halves a, b of shape (..., n/(2j), j) paired at distance j."""
    *lead, n = x.shape
    x2 = x.reshape(*lead, n // (2 * j), 2, j)
    return x2[..., 0, :], x2[..., 1, :]


def _join(a, b):
    *lead, g, j = a.shape
    return jnp.stack([a, b], axis=-2).reshape(*lead, g * 2 * j)


def _compare_exchange(keys, ranks, values, j: int, dir_up, *, ascending: bool):
    """One bitonic substage at partner distance ``j`` (static), vectorized.

    ``dir_up`` is a bool vector over the n/(2j) groups: True means this group
    sorts in comparator order, False in reverse. ``ascending`` is folded into
    the primary comparison; ``ranks`` (optional) break ties -> stable.
    Reshape-based formulation, no gathers — TPU VPU friendly.
    """
    ka, kb = _split(keys, j)
    gt = (ka > kb) if ascending else (ka < kb)  # "a after b" in final order
    if ranks is not None:
        ra, rb = _split(ranks, j)
        gt = gt | ((ka == kb) & (ra > rb))
    swap = gt == dir_up[:, None]
    keys = _join(jnp.where(swap, kb, ka), jnp.where(swap, ka, kb))
    if ranks is not None:
        ranks = _join(jnp.where(swap, rb, ra), jnp.where(swap, ra, rb))
    if values is not None:
        def ex(v):
            va, vb = _split(v, j)
            return _join(jnp.where(swap, vb, va), jnp.where(swap, va, vb))
        values = jax.tree.map(ex, values)
    return keys, ranks, values


def _network(keys, ranks, values, *, ascending: bool):
    """Full bitonic sort network on a power-of-two last axis (static unroll)."""
    n = keys.shape[-1]
    if n == 1:
        return keys, ranks, values
    log_n = n.bit_length() - 1
    for stage in range(1, log_n + 1):  # sorted block size 2**stage
        k = 1 << stage
        for sub in range(stage - 1, -1, -1):  # partner distance 2**sub
            j = 1 << sub
            g = n // (2 * j)
            # group m covers elements [m*2j, (m+1)*2j); its bitonic block id is
            # (m*2j)//k; blocks alternate comparator/reverse-comparator order.
            blk = (jnp.arange(g) * 2 * j) // k
            dir_up = blk % 2 == 0
            keys, ranks, values = _compare_exchange(
                keys, ranks, values, j, dir_up, ascending=ascending
            )
    return keys, ranks, values


def _merge_network(keys, ranks, values, *, ascending: bool):
    """Bitonic *merge* only: last axis must already be a bitonic sequence."""
    n = keys.shape[-1]
    log_n = n.bit_length() - 1
    for sub in range(log_n - 1, -1, -1):
        j = 1 << sub
        g = n // (2 * j)
        dir_up = jnp.ones((g,), bool)
        keys, ranks, values = _compare_exchange(
            keys, ranks, values, j, dir_up, ascending=ascending
        )
    return keys, ranks, values


def _pad_last(x, pad: int, value):
    pad_width = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, pad_width, constant_values=value)


@partial(jax.jit, static_argnames=("ascending", "stable", "has_values"))
def _sort_impl(keys, values, *, ascending: bool, stable: bool, has_values: bool):
    n = keys.shape[-1]
    np2 = next_pow2(n)
    pad = np2 - n
    sent = sentinel_for(keys.dtype, largest=ascending)
    if pad:
        keys = _pad_last(keys, pad, sent)
        if values is not None:
            values = jax.tree.map(lambda v: _pad_last(v, pad, 0), values)
    ranks = None
    if stable:
        ranks = jnp.broadcast_to(
            jnp.arange(np2, dtype=jnp.int32), keys.shape
        )
    keys, _, values = _network(keys, ranks, values, ascending=ascending)
    if pad:
        keys = keys[..., :n]
        if values is not None:
            values = jax.tree.map(lambda v: v[..., :n], values)
    return keys, values


def bitonic_sort(
    keys: jax.Array,
    values=None,
    *,
    ascending: bool = True,
    stable: bool = False,
):
    """Sort ``keys`` along the last axis with a bitonic network.

    ``values`` (array or pytree of arrays, same shape as keys) are permuted
    alongside. Returns sorted keys, or ``(sorted_keys, permuted_values)``.
    """
    k, v = _sort_impl(
        keys, values, ascending=ascending, stable=stable, has_values=values is not None
    )
    return k if values is None else (k, v)


@partial(jax.jit, static_argnames=("ascending", "has_values"))
def _merge_impl(a, b, va, vb, *, ascending: bool, has_values: bool):
    keys = jnp.concatenate([a, jnp.flip(b, axis=-1)], axis=-1)
    values = None
    if has_values:
        values = jax.tree.map(
            lambda x, y: jnp.concatenate([x, jnp.flip(y, axis=-1)], axis=-1), va, vb
        )
    keys, _, values = _merge_network(keys, None, values, ascending=ascending)
    return keys, values


def bitonic_merge_pair(a, b, va=None, vb=None, *, ascending: bool = True):
    """Merge two sorted arrays (equal pow2 last-axis length) into one.

    ``concat(a, reverse(b))`` is bitonic -> a single merge network. This is the
    paper's "merge two sorted lists" step in branch-free form; O(n log n)
    compare-exchanges instead of O(n) sequential merge, but fully vectorized.
    """
    if a.shape[-1] != b.shape[-1]:
        raise ValueError(f"length mismatch {a.shape} vs {b.shape}")
    n = a.shape[-1]
    if n & (n - 1):
        raise ValueError("bitonic_merge_pair requires power-of-two lengths")
    keys, values = _merge_impl(
        a, b, va, vb, ascending=ascending, has_values=va is not None
    )
    return keys if va is None else (keys, values)


def bitonic_topk(x: jax.Array, k: int, *, largest: bool = True):
    """Top-k (values, indices) via the bitonic network (serving-path utility)."""
    idx = jnp.broadcast_to(jnp.arange(x.shape[-1], dtype=jnp.int32), x.shape)
    keys, vals = _sort_impl(
        x, idx, ascending=not largest, stable=True, has_values=True
    )
    return keys[..., :k], vals[..., :k]
