"""Checkpointing: atomic, async-capable, mesh-agnostic restore.

Fault-tolerance posture (DESIGN.md §5):
* **atomic** — write to ``step_NNN.tmp`` then ``os.replace`` to ``step_NNN``;
  a crash mid-save never corrupts the latest checkpoint.
* **async** — ``save(..., blocking=False)`` snapshots to host memory
  (device_get) on the caller thread and writes to disk on a background
  thread, keeping serialization off the training critical path.
* **mesh-agnostic restore** — leaves are stored unsharded (np arrays) with the
  pytree structure; ``restore(..., shardings=...)`` re-shards onto whatever
  mesh the job restarted with (elastic rescale: 256 -> 512 chips just works;
  the dry-run proves both lower).
* **bit-exact resume** — the data-pipeline state (PRNG key, step) is part of
  the checkpoint payload.

Format: one ``.npz`` per checkpoint + a JSON treedef. At real scale this
becomes per-host sharded files; the layout keeps that swap local to _write.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------- paths ---
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def latest_step(self) -> Optional[int]:
        steps = [
            int(d.split("_")[1])
            for d in os.listdir(self.dir)
            if d.startswith("step_") and not d.endswith(".tmp")
        ]
        return max(steps) if steps else None

    # -------------------------------------------------------------- save ---
    def save(self, step: int, tree: Any, *, blocking: bool = True) -> None:
        self.wait()  # one in-flight async save at a time
        flat, treedef = _flatten_with_paths(tree)

        def to_host(x):
            h = np.asarray(jax.device_get(x))
            if h.dtype.kind == "V" or h.dtype.name == "bfloat16":
                h = h.astype(np.float32)  # npz can't store ml_dtypes; lossless
            return h

        host = [to_host(x) for x in flat]
        tdj = json.dumps(jax.tree_util.tree_structure(tree), default=str)

        def write():
            tmp = self._step_dir(step) + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "leaves.npz"), *host)
            with open(os.path.join(tmp, "treedef.json"), "w") as f:
                json.dump({"repr": tdj, "n_leaves": len(host), "step": step}, f)
            final = self._step_dir(step)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.dir)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------ restore ---
    def restore(self, like: Any, *, step: Optional[int] = None, shardings: Any = None):
        """Restore into the structure of ``like``; reshard if asked.

        ``like`` supplies the treedef (and dtypes); ``shardings`` (a matching
        pytree of NamedSharding or None) places each leaf — this is the
        elastic-rescale path.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = self._step_dir(step)
        with np.load(os.path.join(d, "leaves.npz")) as z:
            host = [z[k] for k in z.files]
        flat_like, treedef = _flatten_with_paths(like)
        if len(host) != len(flat_like):
            raise ValueError(
                f"checkpoint has {len(host)} leaves, expected {len(flat_like)}"
            )
        if shardings is None:
            leaves = [jax.numpy.asarray(h, l.dtype) for h, l in zip(host, flat_like)]
        else:
            flat_sh = jax.tree_util.tree_flatten(shardings)[0]
            leaves = [
                jax.device_put(np.asarray(h, l.dtype), s)
                for h, l, s in zip(host, flat_like, flat_sh)
            ]
        return jax.tree_util.tree_unflatten(treedef, leaves), step
