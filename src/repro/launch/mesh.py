"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state. Single pod: (data=16, model=16) = 256 chips
(TPU v5e pod). Multi-pod: (pod=2, data=16, model=16) = 512 chips; the "pod"
axis carries only DP gradient all-reduce (DCN-friendly).
"""
from __future__ import annotations

import jax

SINGLE_POD = (16, 16)
MULTI_POD = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD if multi_pod else SINGLE_POD
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh over however many (host) devices exist — tests/examples."""
    n = 1
    for s in shape:
        n *= s
    assert n <= len(jax.devices()), (shape, len(jax.devices()))
    return jax.make_mesh(shape, axes)
