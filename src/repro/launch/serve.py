"""Batched serving driver: prefill a prompt batch, decode N tokens.

Greedy/temperature sampling over the vocab-parallel logits; the decode loop
uses the serving top-k from the sort engine (repro.engine.topk, a stable
descending argsort) — the serving-path integration from DESIGN.md §3.

``--topk-queue`` routes each row's top-k through the async micro-batching
queue instead (repro.engine.AsyncSortService): every row is an independent
single-request producer, and the queue coalesces them back into one
executable call per step — the serving shape docs/serving.md describes,
with queue stats printed at exit.  ``--adaptive`` (implies ``--topk-queue``)
lets a ``DelayController`` move the flush window with the observed arrival
rate instead of pinning ``max_delay_ms``; ``--stats`` prints the full
service ledger, including the ``overflow_retries`` / ``recompiles``
exchange-path counters that previously vanished from serving telemetry.

``--moe`` serves MoE expert routing through the adaptive exchange engine
instead of decoding: a (deliberately skew-able, ``--moe-skew``) router
dispatches ``--batch x --prompt-len`` tokens per step via
``moe_apply_adaptive``, which runs at the planner's *learned* expert
capacity factor, retries-over-drops on overflow, and feeds the telemetry
ledger ``--stats`` prints (drop/overflow/retry/recompile counts and the
learned factor).  Point ``$REPRO_SORT_PLANS`` at a JSON file and the
learned capacity survives restarts — the second serve run's first step
already sizes expert buffers right (docs/exchange.md).

``--tenants web:3:0,batch:1:1`` routes the top-k path through the
multi-tenant SLO frontend instead (``repro.engine.frontend.SortFrontend``):
decode rows are assigned round-robin across the named tenants (weight and
priority per spec), each stamped with the ``--slo-ms`` deadline, and the
exit line reports per-tenant served counts and SLO misses.  ``--warmup``
AOT-compiles the vocab-size argsort ladder before traffic so the first
decode step pays zero fresh compiles (docs/serving.md).

Usage:
  python -m repro.launch.serve --arch qwen3-0.6b --reduced --batch 4 \
      --prompt-len 32 --gen 16 [--topk-queue] [--adaptive] [--stats]
  python -m repro.launch.serve --moe --batch 4 --prompt-len 64 --gen 8 \
      --experts 8 --moe-skew 6.0 --stats
  python -m repro.launch.serve --reduced --batch 4 --gen 8 \
      --tenants web:3:0,batch:1:1 --warmup --slo-ms 50 --stats
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCHS, reduced
from repro.engine import topk
from repro.models.transformer import ShardCtx, model_init
from repro.train.steps import prefill_step, serve_decode_step


def sample_next(logits: jax.Array, key, *, temperature: float, top_k: int,
                queue=None, frontend=None, tenants=(), ticket_log=None):
    """(B, V) logits -> (B,) token ids. top_k via the engine's stable argsort
    (same tie behaviour as lax.top_k; the serving-path integration).

    With ``queue=`` (an ``AsyncSortService``) each row becomes one
    ``submit_async(kind='argsort', ascending=False)`` request; the queue
    coalesces the B rows into a single executable call per decode step.
    With ``frontend=`` (a ``SortFrontend``) rows are instead submitted
    round-robin across ``tenants`` — each row carries its tenant's SLO
    deadline, and admitted tickets land in ``ticket_log`` so the driver can
    report per-tenant SLO misses at exit.
    """
    if frontend is not None or queue is not None:
        rows = np.asarray(logits, np.float32)
        if frontend is not None:
            futs = [
                frontend.submit(tenants[i % len(tenants)], r,
                                kind="argsort", ascending=False)
                for i, r in enumerate(rows)
            ]
            if ticket_log is not None:
                ticket_log.extend(futs)
        else:
            futs = [queue.submit_async(r, kind="argsort", ascending=False)
                    for r in rows]
        order = np.stack([np.asarray(f.result())[:top_k] for f in futs])
        idx = jnp.asarray(order.astype(np.int32))
        if temperature <= 0:
            return idx[:, 0]
        vals = jnp.take_along_axis(jnp.asarray(rows), idx, axis=1)
    else:
        if temperature <= 0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        vals, idx = topk(logits, top_k)
    probs = jax.nn.softmax(vals / temperature, axis=-1)
    choice = jax.random.categorical(key, jnp.log(jnp.maximum(probs, 1e-20)))
    return jnp.take_along_axis(idx, choice[:, None], axis=1)[:, 0].astype(jnp.int32)


def run_moe_serving(args):
    """--moe: serve expert routing through the adaptive exchange engine.

    Every step dispatches one token batch with ``moe_apply_adaptive`` — the
    MoE consumer of ``repro.exchange`` — through the process-wide planner,
    so expert capacity factors are learned (and, with $REPRO_SORT_PLANS,
    persisted) exactly like model-D sort capacities.  A skewed router pays
    its overflow retry on the first step; every later step — and every step
    of a restarted process — runs at the learned factor with zero retries.
    """
    from repro.engine.planner import default_planner
    from repro.models.moe import (
        MoEConfig,
        collapse_router,
        moe_apply_adaptive,
        moe_init,
        moe_plan_key,
    )

    cfg = MoEConfig(
        d_model=64, d_ff=32, n_experts=args.experts, top_k=args.moe_top_k
    )
    planner = default_planner()
    p = moe_init(jax.random.PRNGKey(args.seed), cfg, jnp.float32, ep_shards=1)
    if args.moe_skew:
        # worst-case routing skew, so the capacity loop has something to
        # learn from (a fresh random router is the near-uniform case the
        # aux loss trains toward — no overflow, no story)
        p = collapse_router(p, args.moe_skew)

    T = args.batch * args.prompt_len
    key = moe_plan_key(T, cfg, jnp.float32)
    rng = np.random.default_rng(args.seed)
    led = planner.telemetry
    # the default planner's ledger is process-wide; snapshot every counter so
    # --stats reports this run's deltas, not whatever ran before in-process
    base = {name: getattr(led, name) for name in (
        "calls", "total_dropped", "total_dropped_averted", "overflow_events",
        "total_retries", "total_recompiles")}
    retries0 = base["total_retries"]

    t_start = time.time()
    y = None
    first_retries = 0
    t_warm = dt = 0.0
    for step in range(args.gen):
        x = jnp.asarray(rng.standard_normal((T, cfg.d_model)), jnp.float32)
        y, aux, counts = moe_apply_adaptive(p, cfg, x, planner=planner)
        if step == 0:
            # step 0 pays the XLA compiles (plus any overflow-retry
            # recompiles); keep it out of the steady-state rate
            jax.block_until_ready(y)
            first_retries = led.total_retries - retries0
            t_warm = time.time() - t_start
            t0 = time.time()
    jax.block_until_ready(y)
    if args.gen > 1:
        dt = time.time() - t0
    steady_steps = max(args.gen - 1, 1)

    cf = planner.capacity_factor_for(key, default=cfg.capacity_factor)
    steady = (
        f"steady {dt / steady_steps * 1e3:.2f} ms/step "
        f"({T * (args.gen - 1) / max(dt, 1e-9):.0f} tokens/s)"
        if args.gen > 1 else "steady n/a (needs --gen >= 2)"
    )
    print(f"moe-serve: experts={cfg.n_experts} top_k={cfg.top_k} "
          f"tokens/step={T} steps={args.gen}")
    print(f"moe-serve: warmup {t_warm * 1e3:.1f} ms "
          f"(retries={first_retries}); {steady} learned_cf={cf:.2f}")
    if args.stats:
        # dropped = tokens the served outputs actually lost (retry budget
        # exhausted); dropped_averted = losses retried attempts recomputed
        # away — the telemetry schema keeps the two separate (docs/exchange.md)
        d = {name: getattr(led, name) - v for name, v in base.items()}
        # routing is constant across this run's steps, so the final
        # observation's required factor IS the run's peak requirement (the
        # ledger-wide peak_factor would mix in pre-run in-process traffic)
        last = led.last(key)
        rf = last.required_factor() if d["calls"] and last else 0.0
        print(f"moe-stats: calls={d['calls']} "
              f"dropped={d['total_dropped']} "
              f"dropped_averted={d['total_dropped_averted']} "
              f"overflows={d['overflow_events']} "
              f"retries={d['total_retries']} "
              f"recompiles={d['total_recompiles']} "
              f"required_factor={rf:.2f}")
    late = led.total_retries - retries0 - first_retries
    if late:
        # later batches out-skewed the learned margin; the learner has
        # already jumped again, so this is a one-off per skew level
        print(f"moe-serve: note — {late} post-warmup retrie(s) "
              f"(skew exceeded the learned margin; factor re-learned)")
    return y


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--top-k", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--topk-queue", action="store_true",
                    help="route per-row top-k through the AsyncSortService "
                         "micro-batching queue (docs/serving.md)")
    ap.add_argument("--adaptive", action="store_true",
                    help="adapt the queue's flush window to the arrival rate "
                         "(DelayController; implies --topk-queue)")
    ap.add_argument("--min-delay-ms", type=float, default=0.1,
                    help="lower bound of the adaptive flush window")
    ap.add_argument("--stats", action="store_true",
                    help="print the full service ledger at exit, incl. the "
                         "overflow_retries / recompiles exchange counters "
                         "(implies --topk-queue: the ledger lives on the "
                         "sort service)")
    ap.add_argument("--moe", action="store_true",
                    help="serve MoE expert routing through the adaptive "
                         "exchange engine instead of decoding; --stats "
                         "prints drop/overflow/retry counts (docs/exchange.md)")
    ap.add_argument("--experts", type=int, default=8,
                    help="expert count for --moe serving")
    ap.add_argument("--moe-top-k", type=int, default=2,
                    help="router top-k for --moe serving")
    ap.add_argument("--moe-skew", type=float, default=6.0,
                    help="router logit bias onto a hot expert subset (0 = "
                         "uniform routing, nothing for the loop to learn)")
    ap.add_argument("--tenants", default="",
                    help="serve the top-k path through the multi-tenant "
                         "SLO frontend (repro.engine.frontend.SortFrontend); "
                         "comma-separated name[:weight[:priority]] specs, "
                         "decode rows assigned round-robin (docs/serving.md)")
    ap.add_argument("--slo-ms", type=float, default=None,
                    help="per-request deadline budget for --tenants rows; "
                         "late rows are still answered (serving must emit a "
                         "token) and counted as SLO misses at exit")
    ap.add_argument("--warmup", action="store_true",
                    help="AOT-compile the serving sort cells (vocab-size "
                         "argsort across the batch ladder) before traffic, "
                         "so the first decode step pays zero compiles")
    args = ap.parse_args(argv)

    if args.moe:
        return run_moe_serving(args)

    frontend = None
    fe_tenants: list = []
    fe_tickets: list = []
    qsvc = None
    if args.tenants:
        from repro.engine import SortFrontend, Tenant
        specs = []
        for spec in args.tenants.split(","):
            parts = spec.split(":")
            specs.append(Tenant(
                parts[0],
                weight=float(parts[1]) if len(parts) > 1 else 1.0,
                priority=int(parts[2]) if len(parts) > 2 else 0,
                slo_ms=args.slo_ms,
            ))
        # shed_expired=False: a decode row must produce a token no matter
        # what, so late rows are served and the miss is counted instead
        frontend = SortFrontend(tenants=specs, max_batch=args.batch,
                                shed_expired=False, start=True)
        fe_tenants = [t.name for t in specs]
    elif args.topk_queue or args.adaptive or args.stats:
        from repro.engine import AsyncSortService
        qsvc = AsyncSortService(
            max_batch=args.batch,
            max_delay_ms=2.0,
            min_delay_ms=args.min_delay_ms if args.adaptive else None,
        )

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduced(cfg)

    if args.warmup:
        # AOT-warm every executable the decode loop's top-k can touch: a
        # descending float32 argsort of one vocab row, at every pow2 batch
        # bucket up to --batch (partial flushes produce partial batches)
        from repro.engine.frontend import warmup as engine_warmup
        svc = frontend.service if frontend is not None else (
            qsvc.service if qsvc is not None else None
        )
        if svc is None:
            from repro.engine import AsyncSortService
            qsvc = AsyncSortService(max_batch=args.batch, max_delay_ms=2.0)
            svc = qsvc.service
        rep = engine_warmup(svc, cells=[(cfg.vocab_size, "float32")],
                            kinds=("argsort",), ascending=(False,),
                            max_batch=args.batch)
        print(rep.summary())

    ctx = ShardCtx()
    key = jax.random.PRNGKey(args.seed)
    params = model_init(key, cfg, ep_shards=ctx.ep_shards)

    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(args.batch, args.prompt_len)), jnp.int32
    )
    fe = None
    if cfg.frontend != "none":
        fe = jnp.asarray(
            rng.standard_normal((args.batch, cfg.n_frontend_tokens, cfg.d_model)),
            cfg.compute_dtype,
        )

    t0 = time.time()
    cache_len = args.prompt_len + args.gen
    logits, cache = jax.jit(
        lambda p, t, f: prefill_step(p, cfg, t, ctx=ctx, frontend_embeds=f,
                                     cache_len=cache_len)
    )(params, prompts, fe)
    t_prefill = time.time() - t0

    decode = jax.jit(lambda p, t, c: serve_decode_step(p, cfg, t, c, ctx=ctx))
    out_tokens = []
    tok = sample_next(logits, key, temperature=args.temperature,
                      top_k=args.top_k, queue=qsvc, frontend=frontend,
                      tenants=fe_tenants, ticket_log=fe_tickets)
    out_tokens.append(tok)
    t0 = time.time()
    for i in range(args.gen - 1):
        key, sub = jax.random.split(key)
        lg, cache = decode(params, tok[:, None], cache)
        tok = sample_next(lg[:, 0], sub, temperature=args.temperature,
                          top_k=args.top_k, queue=qsvc, frontend=frontend,
                          tenants=fe_tenants, ticket_log=fe_tickets)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = np.stack([np.asarray(t) for t in out_tokens], axis=1)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len} gen={args.gen}")
    print(f"prefill {t_prefill*1e3:.1f} ms; decode {t_decode/max(args.gen-1,1)*1e3:.2f} ms/tok")
    print("sampled token ids (first row):", gen[0][:16].tolist())
    if frontend is not None:
        frontend.close()
        st = frontend.stats
        served = " ".join(f"{k}={v}"
                          for k, v in sorted(st.tenant_served.items()))
        misses = sum(1 for t in fe_tickets if not t.slo_met)
        print(f"frontend: tenants[{served}] batches={st.batches} "
              f"fill={st.fill_ratio():.2f} compiles={st.compiles} "
              f"slo_misses={misses}/{len(fe_tickets)} "
              f"shed={st.shed_total()}")
        if args.stats:
            pct = st.latency_percentiles()
            print(f"frontend-stats: requests={st.requests} "
                  f"keys_in={st.keys_in} cache_hits={st.cache_hits} "
                  f"queue p50={pct[50]*1e3:.2f} ms p99={pct[99]*1e3:.2f} ms "
                  f"throughput={st.throughput_keys_per_s():.0f} keys/s")
    if qsvc is not None:
        qsvc.close()
        qs = qsvc.stats
        pct = qs.latency_percentiles()
        print(f"sort-queue: batches={qs.coalesced_batches} "
              f"fill={qs.fill_ratio():.2f} compiles={qs.compiles} "
              f"queue p50={pct[50]*1e3:.2f} ms p99={pct[99]*1e3:.2f} ms")
        if qsvc.delay is not None:
            print(f"adaptive-delay: window={qsvc.delay.delay_ms:.3f} ms "
                  f"(bounds [{qsvc.delay.min_delay_s*1e3:.3f}, "
                  f"{qsvc.delay.max_delay_s*1e3:.3f}]) "
                  f"shrinks={qsvc.delay.shrinks} grows={qsvc.delay.grows} "
                  f"arrival_rate={qsvc.delay.arrival_rate():.1f}/s")
        if args.stats:
            print(f"service-stats: requests={qs.requests} batches={qs.batches} "
                  f"keys_in={qs.keys_in} compiles={qs.compiles} "
                  f"cache_hits={qs.cache_hits} "
                  f"overflow_retries={qs.overflow_retries} "
                  f"recompiles={qs.recompiles} "
                  f"peak_mean_ratio={qs.peak_mean_ratio:.2f} "
                  f"throughput={qs.throughput_keys_per_s():.0f} keys/s")
    assert gen.min() >= 0 and gen.max() < cfg.vocab_size, "pad-vocab leak!"
    return gen


if __name__ == "__main__":
    main()
