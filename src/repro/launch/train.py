"""End-to-end training driver.

Wires together: config registry -> model init -> sharded train_step ->
synthetic data pipeline -> checkpoint manager -> fault-tolerant control loop
(watchdog + anomaly monitor + restore/replay). On this CPU container it runs
reduced configs for real (examples/train_lm.py uses it); on a pod the same
driver runs the full configs — the dry-run proves those lower.

Usage:
  python -m repro.launch.train --arch qwen3-0.6b --steps 50 --reduced \
      --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ARCHS, reduced
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.distributed.fault_tolerance import AnomalyMonitor, run_with_recovery
from repro.models.transformer import ShardCtx, model_init
from repro.optim.adamw import OptConfig, init_opt_state
from repro.train.steps import train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true", help="smoke-size config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--state-dtype", choices=("f32", "int8"), default="f32")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduced(cfg)
    ctx = ShardCtx()  # single-host; pod meshes come from launch/dryrun wiring

    params = model_init(jax.random.PRNGKey(args.seed), cfg, ep_shards=ctx.ep_shards)
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.2f}M steps={args.steps}")

    ocfg = OptConfig(
        peak_lr=args.lr,
        warmup_steps=max(2, args.steps // 10),
        total_steps=args.steps,
        state_dtype=args.state_dtype,
        compress_grads=args.compress_grads,
    )
    opt = init_opt_state(params, ocfg)
    step_fn = jax.jit(
        functools.partial(
            train_step,
            cfg=cfg,
            opt_cfg=ocfg,
            ctx=ctx,
            n_microbatch=args.microbatch,
            loss_chunk=min(64, args.seq),
        )
    )

    pipe = SyntheticLM(cfg.vocab_size, args.batch, args.seq, seed=args.seed)
    data = Prefetcher(iter(pipe))
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    state = {"params": params, "opt": opt}
    t0 = time.time()
    losses = []

    def one_step(i: int) -> dict:
        b = next(data)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        state["params"], state["opt"], m = step_fn(state["params"], state["opt"], batch)
        m = {k: float(v) if jnp.ndim(v) == 0 else v for k, v in m.items()}
        losses.append(m["loss"])
        if (i + 1) % args.log_every == 0:
            dt = (time.time() - t0) / (i + 1)
            print(f"step {i+1:5d} loss {m['loss']:.4f} gnorm {m['grad_norm']:.3f} "
                  f"lr {m['lr']:.2e} {dt*1e3:.0f} ms/step")
        return m

    def save(i: int) -> None:
        if mgr:
            mgr.save(i, {**state, "pipeline": pipe.checkpoint_state()}, blocking=False)

    def restore() -> int:
        if not mgr:
            return 0
        try:
            restored, s = mgr.restore({**state, "pipeline": pipe.checkpoint_state()})
        except FileNotFoundError:
            return 0  # crash before first checkpoint: replay from step 0
        state["params"], state["opt"] = restored["params"], restored["opt"]
        pipe.restore_state(restored["pipeline"])
        return s

    summary = run_with_recovery(
        n_steps=args.steps,
        step_fn=one_step,
        save_fn=save,
        restore_fn=restore,
        checkpoint_every=args.ckpt_every,
        # fresh routers overflow until balanced; short demo runs shouldn't trip
        monitor=AnomalyMonitor(overflow_patience=max(200, args.steps)),
    )
    data.close()
    if mgr:
        mgr.wait()
    print(f"done: loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"({summary['restarts']} restarts)")
    return losses


if __name__ == "__main__":
    main()
