"""End-to-end training driver.

Wires together: config registry -> model init -> sharded train_step ->
synthetic data pipeline -> checkpoint manager -> fault-tolerant control loop
(watchdog + anomaly monitor + restore/replay). On this CPU container it runs
reduced configs for real (examples/train_lm.py uses it); on a pod the same
driver runs the full configs — the dry-run proves those lower.

MoE archs close the capacity-learning loop during training: a
``MoECapacityController`` reads the planner's learned factor before each
step (capacity is static, so a learned bump recompiles the step once),
folds the step's ``moe_dropped``/``moe_peak`` metrics back in afterwards,
and persists factors to the shared plan cache ($REPRO_SORT_PLANS or
--plans) — capacity learned here warms ``serve.py --moe`` and vice versa.
The planner's telemetry ledger feeds ``AnomalyMonitor.watch_exchange``, so
a collapsing router trips recovery instead of silently dropping tokens.

Usage:
  python -m repro.launch.train --arch qwen3-0.6b --steps 50 --reduced \
      --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
  python -m repro.launch.train --arch granite-moe-3b-a800m --reduced \
      --mesh data=2,model=4 --plans /tmp/plans.json
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ARCHS, reduced
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.distributed.fault_tolerance import AnomalyMonitor, run_with_recovery
from repro.models.transformer import ShardCtx, model_init
from repro.optim.adamw import OptConfig, init_opt_state
from repro.train.adaptive import MoECapacityController, parse_mesh_spec
from repro.train.steps import train_step


def _has_moe(cfg) -> bool:
    return cfg.n_experts > 0 and "moe" in cfg.ffn_pattern


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="qwen3-0.6b")
    ap.add_argument("--reduced", action="store_true", help="smoke-size config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--state-dtype", choices=("f32", "int8"), default="f32")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--mesh", default="",
                    help="axis=size,... mesh spec (e.g. data=2,model=4); "
                         "experts shard over the 'model' axis")
    ap.add_argument("--plans", default="",
                    help="plan-cache path for learned MoE capacity factors "
                         "(default: $REPRO_SORT_PLANS via the process planner)")
    ap.add_argument("--moe-skew", type=float, default=0.0,
                    help="collapse every MoE router at this logit scale — "
                         "worst-case skew for capacity-loop demos/tests")
    args = ap.parse_args(argv)

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = reduced(cfg)
    if args.mesh:
        mesh, axes = parse_mesh_spec(args.mesh)
        ctx = ShardCtx(mesh=mesh, axes=axes)
    else:
        ctx = ShardCtx()  # single-device; pod meshes come via --mesh

    params = model_init(jax.random.PRNGKey(args.seed), cfg, ep_shards=ctx.ep_shards)
    if args.moe_skew and _has_moe(cfg):
        from repro.models.moe import collapse_router

        def skew(gp):
            return {**gp, "moe": collapse_router(gp["moe"], args.moe_skew)}

        params["blocks"] = {
            pos: skew(gp) if "moe" in gp else gp
            for pos, gp in params["blocks"].items()
        }
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.2f}M steps={args.steps}")

    ocfg = OptConfig(
        peak_lr=args.lr,
        warmup_steps=max(2, args.steps // 10),
        total_steps=args.steps,
        state_dtype=args.state_dtype,
        compress_grads=args.compress_grads,
    )
    opt = init_opt_state(params, ocfg)

    controller = planner = None
    if _has_moe(cfg):
        from repro.engine.planner import Planner, default_planner

        planner = Planner(args.plans) if args.plans else default_planner()
        controller = MoECapacityController(
            cfg.moe_cfg(),
            tokens=args.batch * args.seq // args.microbatch,
            ctx=ctx,
            planner=planner,
            dtype=cfg.compute_dtype,
        )

    @functools.lru_cache(maxsize=None)
    def step_fn_for(moe_capacity):
        # one executable per learned capacity — the static-arg recompile
        # that makes a capacity bump cost one compile, like serving
        return jax.jit(
            functools.partial(
                train_step,
                cfg=cfg,
                opt_cfg=ocfg,
                ctx=ctx,
                n_microbatch=args.microbatch,
                loss_chunk=min(64, args.seq),
                moe_capacity=moe_capacity,
            )
        )

    pipe = SyntheticLM(cfg.vocab_size, args.batch, args.seq, seed=args.seed)
    data = Prefetcher(iter(pipe))
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    state = {"params": params, "opt": opt}
    t0 = time.time()
    losses = []

    def one_step(i: int) -> dict:
        b = next(data)
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        cap = controller.capacity if controller else None
        step_fn = step_fn_for(cap)
        state["params"], state["opt"], m = step_fn(state["params"], state["opt"], batch)
        m = {k: float(v) if jnp.ndim(v) == 0 else v for k, v in m.items()}
        if controller:
            # between-step learning: fold this step's dropped/peak into the
            # planner so the next step's capacity covers the observed skew
            controller.observe(m, capacity=cap)
        losses.append(m["loss"])
        if (i + 1) % args.log_every == 0:
            dt = (time.time() - t0) / (i + 1)
            moe = (
                f" moe[cap {cap} drop {int(m['moe_dropped'])} "
                f"peak {int(m['moe_peak'])}]"
                if controller else ""
            )
            print(f"step {i+1:5d} loss {m['loss']:.4f} gnorm {m['grad_norm']:.3f} "
                  f"lr {m['lr']:.2e} {dt*1e3:.0f} ms/step{moe}")
        return m

    def save(i: int) -> None:
        if mgr:
            mgr.save(i, {**state, "pipeline": pipe.checkpoint_state()}, blocking=False)

    def restore() -> int:
        if not mgr:
            return 0
        try:
            restored, s = mgr.restore({**state, "pipeline": pipe.checkpoint_state()})
        except FileNotFoundError:
            return 0  # crash before first checkpoint: replay from step 0
        state["params"], state["opt"] = restored["params"], restored["opt"]
        pipe.restore_state(restored["pipeline"])
        return s

    # fresh routers overflow until balanced; short demo runs shouldn't trip
    monitor = AnomalyMonitor(overflow_patience=max(200, args.steps))
    if planner is not None:
        # served MoE drops observed by the controller accrue into the
        # routing-collapse counter — training now trips recovery on a
        # collapsing router instead of silently dropping tokens
        monitor.watch_exchange(planner.telemetry)

    summary = run_with_recovery(
        n_steps=args.steps,
        step_fn=one_step,
        save_fn=save,
        restore_fn=restore,
        checkpoint_every=args.ckpt_every,
        monitor=monitor,
    )
    data.close()
    if mgr:
        mgr.wait()
    if controller is not None and planner.path:
        # debounced saves may have skipped the last in-memory move; make the
        # learned factor durable so serving warm-starts from this run
        planner.save()
    if controller is not None:
        print(f"moe: learned_cf={controller.factor:.2f} "
              f"capacity={controller.capacity} cell={controller.key}")
    print(f"done: loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"({summary['restarts']} restarts)")
    return losses


if __name__ == "__main__":
    main()
