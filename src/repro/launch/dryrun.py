import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: 512 placeholder
host devices stand in for 2 TPU v5e pods. For each cell we AOT-lower the right
step function (train_step / prefill_step / serve_decode_step) with
ShapeDtypeStruct inputs carrying their production NamedShardings, compile,
and record:

  * memory_analysis()  — bytes per device (proves it fits)
  * cost_analysis()    — HLO FLOPs / bytes for §Roofline
  * collective bytes   — parsed from the optimized HLO (all-gather,
    all-reduce, reduce-scatter, all-to-all, collective-permute)

Artifacts land in artifacts/dryrun/<arch>__<shape>__<mesh>.json; benchmarks/
roofline.py consumes them.

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import re
import sys
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ARCHS, SHAPES, all_cells, cell_applicable, input_specs
from repro.distributed.sharding import (
    batch_specs,
    cache_specs,
    opt_state_specs,
    param_specs,
    to_named,
)
from repro.launch.mesh import make_production_mesh
from repro.models.transformer import ModelConfig, ShardCtx, init_cache, model_init
from repro.optim.adamw import OptConfig, init_opt_state
from repro.train.steps import prefill_step, serve_decode_step, train_step

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute"
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _line_bytes(type_str: str) -> int:
    nbytes = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        nbytes += n * _DTYPE_BYTES[dt]
    return nbytes


_COLL_RE = re.compile(
    r"=\s+(.+?)\s+((?:all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)[\w-]*)\("
)
_WHILE_RE = re.compile(r"while\(.*?body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"while\(.*?condition=%?([\w\.\-]+)")
_CALL_RE = re.compile(r"(?:to_apply|calls)=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _split_computations(hlo_text: str) -> dict:
    """computation name -> list of body lines (optimized HLO text format)."""
    comps, name, body = {}, None, []
    for line in hlo_text.splitlines():
        if (
            line
            and not line.startswith((" ", "}"))
            and line.rstrip().endswith("{")
            and "->" in line
        ):
            m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", line)
            if m:
                name, body = m.group(1), []
                comps[name] = body
                continue
        if line.startswith("}"):
            name = None
        elif name is not None:
            body.append(line.strip())
    return comps


def collective_bytes(hlo_text: str) -> dict:
    """Sum collective bytes in optimized HLO, *multiplying by loop trip counts*.

    jax scans lower to `while` ops: a collective inside the layer scan runs
    G times per step, inside the microbatch scan G*mb times. cost_analysis()
    ignores loop trip counts (refuted hypothesis H-acct, EXPERIMENTS.md §Perf)
    so we walk the computation graph and multiply. Trip counts are read from
    the loop condition's s32 constant (jax emits constant trip counts for
    scan); heuristic: the max s32 constant in the condition body.
    """
    comps = _split_computations(hlo_text)
    entry = next((n for n in comps if "main" in n), None)
    if entry is None and comps:
        entry = next(iter(comps))

    trip_re = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')

    def cond_trips(line: str, cond_name: str) -> int:
        m = trip_re.search(line)  # XLA records the trip count on the while op
        if m:
            return int(m.group(1))
        consts = [int(c) for c in _CONST_RE.findall("\n".join(comps.get(cond_name, [])))]
        return max(consts) if consts else 1

    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    active = set()

    def walk(name: str, mult: float):
        if name not in comps or name in active:
            return
        active.add(name)
        for line in comps[name]:
            cm = _COLL_RE.search(line)
            if cm and not cm.group(2).endswith("-done"):
                kind = next(k for k in _COLLECTIVES if cm.group(2).startswith(k))
                out[kind] += int(_line_bytes(cm.group(1)) * mult)
                counts[kind] += int(mult)
            wm = _WHILE_RE.search(line)
            if wm:
                cnd = _COND_RE.search(line)
                trips = cond_trips(line, cnd.group(1) if cnd else "")
                walk(wm.group(1), mult * trips)
                continue
            fm = _CALL_RE.search(line)
            if fm:
                walk(fm.group(1), mult)
        active.discard(name)

    if entry:
        walk(entry, 1.0)
    return {"bytes": out, "counts": counts, "total_bytes": sum(out.values())}


def _shaped(tree, specs, mesh):
    named = to_named(specs, mesh, like=tree)

    def one(leaf, ns):
        return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype, sharding=ns)

    return jax.tree.map(one, tree, named)


def build_cell(arch: str, shape: str, mesh):
    """Returns (fn, shaped_args tuple) ready for jit(...).lower(*args)."""
    cfg = ARCHS[arch]
    from dataclasses import replace
    cf = os.environ.get("DRYRUN_CF")
    if cf and cfg.n_experts:
        cfg = replace(cfg, capacity_factor=float(cf))
    if os.environ.get("DRYRUN_COMPRESS_DISPATCH") and cfg.n_experts:
        cfg = replace(cfg, compress_dispatch=True)
    S, B, kind = SHAPES[shape]
    ctx = ShardCtx(mesh=mesh, axes=tuple(mesh.axis_names), ep_axis="model")

    p_shapes = jax.eval_shape(partial(model_init, cfg=cfg, ep_shards=ctx.ep_shards),
                              jax.random.PRNGKey(0))
    pspecs = param_specs(p_shapes)
    p_in = _shaped(p_shapes, pspecs, mesh)

    specs_in = input_specs(cfg, shape)
    b_in = _shaped(specs_in, batch_specs(specs_in), mesh)

    if kind == "train":
        # int8 moments for the giants (DESIGN.md §5), f32 otherwise
        ocfg = OptConfig(state_dtype="int8" if cfg.param_count() > 3e10 else "f32")
        n_micro = int(os.environ.get("DRYRUN_MICROBATCH", "4"))
        o_shapes = jax.eval_shape(partial(init_opt_state, cfg=ocfg), p_shapes)
        o_in = _shaped(o_shapes, opt_state_specs(o_shapes, pspecs), mesh)

        def fn(params, opt_state, batch):
            return train_step(
                params, opt_state, batch, cfg=cfg, opt_cfg=ocfg, ctx=ctx,
                loss_chunk=512, remat=True, n_microbatch=n_micro,
            )

        return fn, (p_in, o_in, b_in)

    if kind == "prefill":
        def fn(params, batch):
            return prefill_step(
                params, cfg, batch["tokens"], ctx=ctx,
                frontend_embeds=batch.get("frontend_embeds"),
            )

        return fn, (p_in, b_in)

    # decode: one token against a cache of length S
    c_shapes = jax.eval_shape(partial(init_cache, cfg=cfg, batch=B, max_len=S))
    c_in = _shaped(c_shapes, cache_specs(c_shapes, cfg), mesh)

    def fn(params, batch, cache):
        return serve_decode_step(params, cfg, batch["tokens"], cache, ctx=ctx)

    return fn, (p_in, b_in, c_in)


def run_cell(arch: str, shape: str, mesh_kind: str, outdir: str) -> dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    t0 = time.time()
    fn, args = build_cell(arch, shape, mesh)
    with jax.set_mesh(mesh):
        lowered = jax.jit(fn).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    n_dev = mesh.devices.size
    rec = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_kind,
        "n_devices": n_dev,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes_per_device": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes_per_device": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes_per_device": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes_per_device": (
                getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "output_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0)
            ),
        },
        "cost": {k: cost.get(k) for k in ("flops", "bytes accessed", "transcendentals")
                 if isinstance(cost, dict) and k in cost},
        "collectives": coll,
    }
    if outdir:
        os.makedirs(outdir, exist_ok=True)
        path = os.path.join(outdir, f"{arch}__{shape}__{mesh_kind}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--mesh", choices=("pod", "multipod", "both"), default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    meshes = ("pod", "multipod") if args.mesh == "both" else (args.mesh,)
    cells = all_cells() if args.all else [(args.arch, args.shape)]
    failures = 0
    for arch, shape in cells:
        if not cell_applicable(arch, shape):
            print(f"SKIP {arch} {shape} (documented: needs sub-quadratic path)")
            continue
        for mk in meshes:
            try:
                rec = run_cell(arch, shape, mk, args.out)
                peak = rec["memory"]["peak_bytes_per_device"] or 0
                print(
                    f"OK   {arch:28s} {shape:12s} {mk:8s} "
                    f"peak/dev={peak/2**30:7.2f}GiB "
                    f"flops={rec['cost'].get('flops', float('nan')):.3e} "
                    f"coll={rec['collectives']['total_bytes']/2**30:.2f}GiB "
                    f"compile={rec['compile_s']:.0f}s"
                )
            except Exception as e:
                failures += 1
                print(f"FAIL {arch} {shape} {mk}: {type(e).__name__}: {e}")
                traceback.print_exc(limit=3)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
