"""MusicGen-medium 1.5B: decoder-only over EnCodec tokens (vocab 2048),
MHA (kv=24), plain GELU FFN. [arXiv:2306.05284; hf]
Frontend STUB: conditioning embeddings provided by input_specs; the 4-codebook
delay pattern is collapsed to one stream (assignment: backbone only)."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, head_dim=64,
    d_ff=6144, vocab_size=2048,
    mlp_gated=False,
    frontend="audio", n_frontend_tokens=64,
    notes="Audio decoder: backbone per assignment. Dense arch: sort technique "
          "inapplicable.",
)
