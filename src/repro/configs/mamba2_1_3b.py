"""Mamba2-1.3B: attention-free SSD (state-space duality), state 128.
[arXiv:2405.21060; unverified]"""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    n_layers=48, d_model=2048, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=50280,
    pattern=("mamba",), ffn_pattern=(None,),
    ssm_state=128, ssm_head_dim=64,
    notes="Attention-free: paper technique inapplicable to the layer stack "
          "(DESIGN.md §6); long_500k runs (O(1) state decode).",
)
