"""Gemma3-12B: 5:1 local:global attention cadence, window 1024, qk-norm,
128k context. [hf:google/gemma-3-1b-pt; unverified]"""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=15360, vocab_size=262144,
    pattern=("attn_l",) * 5 + ("attn",),
    ffn_pattern=("dense",) * 6,
    sliding_window=1024, qk_norm=True,
    rope_theta=1_000_000.0, rope_theta_local=10_000.0,
    notes="5:1 sliding-window cadence -> sub-quadratic serving memory; "
          "long_500k runs (ring-buffer local KV). Dense: sort technique "
          "inapplicable to FFN path.",
)
