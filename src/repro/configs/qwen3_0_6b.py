"""Qwen3-0.6B: qk_norm, GQA kv=8, head_dim 128 decoupled from d_model.
[hf:Qwen/Qwen3-8B; hf]"""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=3072, vocab_size=151936,
    qk_norm=True, rope_theta=1_000_000.0,
    notes="Dense arch: sort technique inapplicable (DESIGN.md §6).",
)
