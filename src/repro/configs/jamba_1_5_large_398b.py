"""Jamba-1.5-large 398B: Mamba+attention 1:7 interleave, MoE 16e top-2 every
second layer. [arXiv:2403.19887; hf]"""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=65536,
    pattern=("mamba", "mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba"),
    ffn_pattern=("dense", "moe", "dense", "moe", "dense", "moe", "dense", "moe"),
    n_experts=16, top_k=2,
    ssm_state=128, ssm_head_dim=64,
    remat_policy="none",
    notes="Hybrid MoE: sort-based EP dispatch on 36 MoE layers; long_500k runs "
          "(9 attn layers hold KV; 63 mamba layers O(1) state).",
)
