"""Qwen2-7B: GQA kv=4, QKV bias. [arXiv:2407.10671; hf]"""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-7b",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, head_dim=128,
    d_ff=18944, vocab_size=152064,
    qkv_bias=True, rope_theta=1_000_000.0,
    notes="Dense arch: sort technique inapplicable (DESIGN.md §6).",
)
