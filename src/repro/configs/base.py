"""Architecture registry + assigned input shapes + smoke-test reduction.

``ARCHS`` maps the assignment's arch ids to full ModelConfigs (exercised only
via the dry-run: ShapeDtypeStruct, no allocation). ``reduced()`` produces the
same-family tiny config the CPU smoke tests instantiate for real.
"""
from __future__ import annotations

import math
from dataclasses import replace
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.transformer import ModelConfig

from .command_r_35b import CONFIG as _command_r
from .dbrx_132b import CONFIG as _dbrx
from .gemma3_12b import CONFIG as _gemma3
from .granite_moe_3b_a800m import CONFIG as _granite
from .internvl2_2b import CONFIG as _internvl2
from .jamba_1_5_large_398b import CONFIG as _jamba
from .mamba2_1_3b import CONFIG as _mamba2
from .musicgen_medium import CONFIG as _musicgen
from .qwen2_7b import CONFIG as _qwen2
from .qwen3_0_6b import CONFIG as _qwen3

ARCHS: Dict[str, ModelConfig] = {
    c.name: c
    for c in [
        _dbrx,
        _granite,
        _internvl2,
        _qwen3,
        _command_r,
        _qwen2,
        _gemma3,
        _musicgen,
        _mamba2,
        _jamba,
    ]
}

# assignment shape table: name -> (seq_len, global_batch, kind)
SHAPES = {
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}

# archs with a sub-quadratic serving path (SSM / hybrid / 5:1 local window)
SUBQUADRATIC = {"mamba2-1.3b", "jamba-1.5-large-398b", "gemma3-12b"}


def cell_applicable(arch: str, shape: str) -> bool:
    """long_500k is skipped for pure full-attention archs (DESIGN.md §6)."""
    if shape == "long_500k":
        return arch in SUBQUADRATIC
    return True


def all_cells():
    return [
        (a, s) for a in ARCHS for s in SHAPES if cell_applicable(a, s)
    ]


def input_specs(cfg: ModelConfig, shape: str):
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train   -> {tokens (B,S), labels (B,S) [, frontend_embeds (B,F,D)]}
    prefill -> {tokens (B,S) [, frontend_embeds]}
    decode  -> {tokens (B,1)} (cache is built separately via cache_specs)
    """
    S, B, kind = SHAPES[shape]
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    specs = {}
    if kind == "train":
        specs = {"tokens": tok, "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    elif kind == "prefill":
        specs = {"tokens": tok}
    else:  # decode: one new token against a cache of length S
        specs = {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
    if cfg.frontend != "none" and kind != "decode":
        specs["frontend_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.n_frontend_tokens, cfg.d_model), cfg.compute_dtype
        )
    return specs


def cache_specs(cfg: ModelConfig, shape: str):
    """ShapeDtypeStructs of the decode cache for this cell (no allocation)."""
    from repro.models.transformer import init_cache

    S, B, kind = SHAPES[shape]
    assert kind == "decode"
    return jax.eval_shape(lambda: init_cache(cfg, B, S))


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Same-family tiny config for CPU smoke tests (one pattern group)."""
    is_attn = any(k.startswith("attn") for k in cfg.pattern)
    return replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=len(cfg.pattern),
        d_model=64,
        n_heads=4 if is_attn else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if is_attn else 0,
        head_dim=16 if is_attn else 0,
        d_ff=0 if cfg.d_ff == 0 else 96,
        vocab_size=128,
        n_experts=min(cfg.n_experts, 5) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        capacity_factor=4.0,  # tiny batches + fresh routers overflow cf=2

        sliding_window=8 if cfg.sliding_window else 0,
        ssm_state=16,
        ssm_head_dim=16,
        ssm_chunk=8,
        n_frontend_tokens=4 if cfg.frontend != "none" else 0,
        kv_chunk=16,
        param_dtype=jnp.float32,
        compute_dtype=jnp.float32,
    )
