"""Command-R 35B: GQA kv=8, no biases, large vocab.
[hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=22528, vocab_size=256000,
    rope_theta=4_000_000.0,
    remat_policy="none",
    notes="Dense arch: sort technique inapplicable (DESIGN.md §6).",
)
