"""DBRX-base 132B: MoE 16 experts top-4, fine-grained; GQA kv=8.
[hf:databricks/dbrx-base; unverified]"""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=10752, vocab_size=100352,
    pattern=("attn",), ffn_pattern=("moe",),
    n_experts=16, top_k=4,
    remat_policy="none",
    notes="MoE arch: paper technique (sort-based EP dispatch) on every layer.",
)
