"""Granite-3.0 MoE 3B-a800m: 40 experts top-8, fine-grained d_ff=512.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49155,
    pattern=("attn",), ffn_pattern=("moe",),
    n_experts=40, top_k=8,
    notes="40 experts on 16 EP shards: experts padded to 48 (3/shard), "
          "router masks the 8 dummies — stresses bucket!=shard mapping.",
)
