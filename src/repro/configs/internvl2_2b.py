"""InternVL2-2B: InternViT frontend (STUBBED) + InternLM2-1.8B backbone.
[arXiv:2404.16821; hf] — input_specs provides precomputed patch embeddings."""
from repro.models.transformer import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=92553,
    frontend="vision", n_frontend_tokens=256,
    notes="VLM: backbone only per assignment; 256 patch-embedding stub tokens "
          "prepended. Dense arch: sort technique inapplicable to FFN path.",
)
