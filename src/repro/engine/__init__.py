"""repro.engine — autotuned sort-plan engine (serving-grade front end).

planner  : SortPlan + autotuner + persistent JSON plan cache
cache    : compiled-executable cache with pow2 shape bucketing
kv       : sort_kv / argsort / sort_pairs / topk — records, not just keys
service  : SortService — ragged batches in, zero-recompile sorts out
"""
from .cache import CompiledCache, size_bucket
from .kv import argsort, cluster_sort_kv, sort_kv, sort_pairs, topk
from .planner import (
    Planner,
    SortPlan,
    autotune,
    default_planner,
    mesh_fingerprint,
    plan_from_strategy,
    plan_key,
    run_plan,
)
from .service import ServiceStats, SortService

__all__ = [
    "CompiledCache",
    "size_bucket",
    "argsort",
    "cluster_sort_kv",
    "sort_kv",
    "sort_pairs",
    "topk",
    "Planner",
    "SortPlan",
    "autotune",
    "default_planner",
    "mesh_fingerprint",
    "plan_from_strategy",
    "plan_key",
    "run_plan",
    "ServiceStats",
    "SortService",
]
