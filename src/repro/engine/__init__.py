"""repro.engine — autotuned sort-plan engine (serving-grade front end).

planner  : SortPlan + autotuner + persistent JSON plan cache; candidate
           sweep covers local_impl='pallas' with a tuned block_n grid;
           folds learned capacity factors into cluster plans
adapt    : closed-loop tuning — ExchangeTelemetry + CapacityLearner turn
           observed model-D overflow into learned capacity factors, and
           DelayController adapts the async flush window to arrival rate
cache    : compiled-executable cache with pow2 shape bucketing
kv       : sort_kv / argsort / sort_pairs / topk — records, not just keys
           (impl='pallas' runs the kernels' stable (key, rank) network)
service  : SortService — ragged batches in, zero-recompile sorts out
queue    : AsyncSortService — async request queue that micro-batches
           individual submit_async calls across callers (docs/serving.md)
frontend : SLO-aware multi-tenant serving front end — AOT warmup of the
           whole plan-cache executable ladder, per-tenant weighted
           admission with EDF dispatch and reject-with-reason load shed,
           and a reproducible open-loop load harness (docs/serving.md)

See docs/architecture.md for the layer map and request lifecycle.
"""
from .adapt import (
    CapacityLearner,
    DelayController,
    ExchangeObservation,
    ExchangeTelemetry,
    LearnedCapacity,
    ManualClock,
)
from .cache import CompiledCache, size_bucket
from .kv import argsort, cluster_sort_kv, sort_kv, sort_pairs, topk
from .planner import (
    Planner,
    SortPlan,
    autotune,
    default_planner,
    mesh_fingerprint,
    parse_plan_key,
    plan_from_strategy,
    plan_key,
    run_plan,
)
from .frontend import (
    LoadReport,
    ShedError,
    SortFrontend,
    Tenant,
    Ticket,
    WarmupReport,
    make_trace,
    run_load,
    warmup,
)
from .queue import AsyncSortService, QueueStats
from .service import ServiceStats, SortService

__all__ = [
    "CapacityLearner",
    "DelayController",
    "ExchangeObservation",
    "ExchangeTelemetry",
    "LearnedCapacity",
    "ManualClock",
    "CompiledCache",
    "size_bucket",
    "argsort",
    "cluster_sort_kv",
    "sort_kv",
    "sort_pairs",
    "topk",
    "Planner",
    "SortPlan",
    "autotune",
    "default_planner",
    "mesh_fingerprint",
    "parse_plan_key",
    "plan_from_strategy",
    "plan_key",
    "run_plan",
    "ServiceStats",
    "SortService",
    "AsyncSortService",
    "QueueStats",
    "LoadReport",
    "ShedError",
    "SortFrontend",
    "Tenant",
    "Ticket",
    "WarmupReport",
    "make_trace",
    "run_load",
    "warmup",
]
