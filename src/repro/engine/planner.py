"""Autotuned sort planning — measure the paper's crossover instead of guessing.

The paper's empirical core: which hybrid wins is workload-dependent ("Hybrid
Quicksort and Merge sort outperformed [the cluster model] ... when sorting
small size data, but with larger data the speedup of [the cluster model]
becomes bigger").  A ``SortPlan`` pins one concrete execution recipe
(strategy, local sort impl, thread count, capacity factor, partitioner mode);
``autotune`` microbenchmarks every candidate for a (size-bucket, dtype, mesh
fingerprint) cell and persists the winner to a JSON plan cache so serving
processes start with tuned choices.

Plan-cache file format (versioned, human-editable)::

    {"version": 3,
     "plans": {"<size_bucket>|<dtype>|<mesh_fp>": {"strategy": "shared",
                                                   "partition": null, ...}},
     "learned": {"<size_bucket>|<dtype>|<mesh_fp>": {"capacity_factor": 3.75,
                                                     "peak_factor": 3.0,
                                                     "observations": 7,
                                                     "partition": null,
                                                     "skew_strikes": 0}}}

The ``learned`` section (schema v2) is the capacity-learning feedback loop's
persistent state: per-cell capacity factors distilled from observed exchange
telemetry (repro.engine.adapt), so a restarted serving process sizes model-D
slabs right on its first compile.  Schema v3 adds the partition policy:
``SortPlan.partition`` pins a plan's partition family, and the learned
entries carry the skew-promotion latch (``partition``/``skew_strikes``) the
``CapacityLearner`` flips when a radix-partitioned cell's peak/mean bucket
ratio stays high — plus the probation counters (``calm_streak``/
``demotions``, additive within v3) that let a promoted cell demote back to
radix after a long calm stretch — see docs/plan-cache.md.  Version-1 and -2
files load fine — they simply carry no learned state / no partition policy.
Cells are keyed by any string the reporting path binds: sort cells use
``<size_bucket>|<dtype>|<mesh_fp>`` (``plan_key``), MoE dispatch cells use
``moe/E<experts>k<top_k>|<token_bucket>|<dtype>|<mesh_fp>``
(``models.moe.moe_plan_key``) — one learned table serves every
``repro.exchange`` consumer.

Under multi-process ``jax.distributed``, ``Planner.autotune`` runs a
**rank-coordinated** sweep: barriers align every rank on each candidate,
per-rank median-of-reps timings reduce by max over ranks, rank 0's winner is
broadcast so every rank proceeds bit-identically, and rank 0 alone writes
the plan file (single-writer election) through the fcntl-locked
merge-on-save path.  Those cells carry the ``/procs<P>x<D>`` fingerprint
suffix, so a later single-process server warm-starts from them only via an
explicit ``fingerprint=`` lookup, never by accident.
"""
from __future__ import annotations

import json
import os
import threading
import time
import warnings
import weakref
from contextlib import contextmanager
from dataclasses import asdict, dataclass, replace
from typing import Dict, Optional

try:  # advisory plan-file locking is POSIX-only; elsewhere merge-on-save
    import fcntl  # still unions concurrent writers, just without mutual
except ImportError:  # exclusion of the read-merge-write itself
    fcntl = None  # type: ignore[assignment]

import jax
import jax.numpy as jnp

from repro.core.bitonic import next_pow2
from repro.core.cluster_sort import cluster_sort
from repro.core.distributed_sort import distributed_merge_sort
from repro.core.seqsort import LOCAL_SORTS
from repro.core.shared_sort import shared_memory_sort
from repro.exchange import PARTITION_MODES, partition_of

from .adapt import CapacityLearner, ExchangeObservation, ExchangeTelemetry, LearnedCapacity

__all__ = [
    "SortPlan",
    "Planner",
    "default_planner",
    "mesh_fingerprint",
    "plan_key",
    "parse_plan_key",
    "plan_from_strategy",
    "run_plan",
    "autotune",
    "LEARNED_SCOPES",
    "PALLAS_BLOCK_SWEEP",
    "PALLAS_INTERPRET_MAX",
]

# how learned capacity factors are keyed across a multi-process deployment:
# 'global' shares one entry per cell (every rank reads/merges the same key —
# the most conservative rank wins), 'per_host' suffixes keys with
# '@h<process_index>' so hosts with host-local skew learn independently
LEARNED_SCOPES = ("global", "per_host")


@contextmanager
def _plan_file_lock(path: str):
    """Advisory ``fcntl`` lock serializing read-merge-write on one plan file.

    Taken on a ``<path>.lock`` sidecar (never the plan file itself: the
    writer atomically ``os.replace``s the plan file, which would drop any
    lock held on the replaced inode).  Cooperating writers — other ranks of
    a ``jax.distributed`` job, other processes sharing ``$REPRO_SORT_PLANS``
    — block here until the current read-merge-write completes.
    """
    if fcntl is None:
        yield
        return
    with open(f"{path}.lock", "w") as lockf:
        fcntl.flock(lockf, fcntl.LOCK_EX)
        try:
            yield
        finally:
            fcntl.flock(lockf, fcntl.LOCK_UN)

_PLAN_VERSION = 3
# v1 = plans only, no learned section; v2 = learned capacity factors but no
# partition policy (plans/entries load with partition=None, strikes=0)
_LOADABLE_VERSIONS = (1, 2, _PLAN_VERSION)

# the learner floor handed to *promoted* (sample-partition) cells: the
# balanced partition needs almost no headroom, so the capacity factor a
# skewed radix history inflated decays back toward ~1 instead of toward the
# radix-era default
SAMPLE_DEFAULT_FACTOR = 1.25

# strategy names: 'shared' covers paper models A/B (A = local_impl='merge',
# B = local_impl='xla'/'bitonic'); C and D keep their api.py names.
_PLAN_STRATEGIES = ("shared", "distributed_merge", "cluster")


@dataclass(frozen=True)
class SortPlan:
    """One executable sort recipe; ``us_per_call`` records the tuned timing.

    ``block_n`` is the Pallas kernel's VMEM tile width; it is only meaningful
    for ``local_impl='pallas'`` and rides through the JSON plan cache so a
    plan tuned on a TPU ships with its winning tile size.

    ``partition`` (schema v3) pins the cluster partition *family* —
    ``"radix"`` (digit/range bucketing: fast, skew-fragile) or ``"sample"``
    (splitter bucketing: balanced under any distribution).  ``None`` means
    "whatever family ``mode`` itself belongs to"; a non-None value that
    disagrees with ``mode`` overrides it (that is how skew promotion flips a
    radix plan to sample mode without forgetting the tuned mode).

    >>> plan = SortPlan("shared", local_impl="pallas", block_n=512)
    >>> SortPlan.from_dict(plan.to_dict()) == plan
    True
    >>> SortPlan("cluster", mode="range").effective_partition()
    'radix'
    >>> SortPlan("cluster", mode="range", partition="sample").partitioner_mode()
    'sample'
    """

    strategy: str = "shared"
    local_impl: str = "xla"
    n_threads: int = 8
    capacity_factor: float = 2.0
    mode: str = "splitters"
    block_n: Optional[int] = None
    us_per_call: float = -1.0
    partition: Optional[str] = None

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "SortPlan":
        known = {k: d[k] for k in cls.__dataclass_fields__ if k in d}
        return cls(**known)

    def effective_partition(self) -> str:
        """The partition family this plan runs: the explicit ``partition``
        override if set, else ``mode``'s own family."""
        return self.partition or partition_of(self.mode)

    def partitioner_mode(self) -> str:
        """The concrete partitioner mode ``run_plan`` should execute.

        ``mode`` itself when it already belongs to ``effective_partition``'s
        family; otherwise the family's canonical mode (``"sample"`` /
        ``"radix"``) — a promoted radix plan runs sample splitters.
        """
        if self.partition is None or partition_of(self.mode) == self.partition:
            return self.mode
        return "sample" if self.partition == "sample" else "radix"


def mesh_fingerprint(mesh=None) -> str:
    """Stable id for the hardware layout a plan was tuned on.

    Single-process fingerprints are ``local/<platform>`` (no mesh) or
    ``<platform>/<axis>=<size>,...`` (mesh plans).  Under multi-process
    ``jax.distributed`` the same device count can describe very different
    hardware — 4 devices might be one host or four — so the fingerprint
    appends ``/procs<process_count>x<devices_per_process>``: a plan tuned on
    a 2-process x 2-device topology never masquerades as a single-host
    4-device plan (the collectives it was timed over cross real process
    boundaries).  Single-process fingerprints are unchanged, so existing
    plan-cache files stay valid.

    >>> mesh_fingerprint().split("/")[0]   # no mesh: 'local/<platform>'
    'local'
    """
    procs = jax.process_count()
    topo = f"/procs{procs}x{jax.local_device_count()}" if procs > 1 else ""
    if mesh is None:
        dev = jax.devices()[0]
        return f"local/{dev.platform}{topo}"
    axes = ",".join(f"{name}={size}" for name, size in mesh.shape.items())
    return f"{mesh.devices.flat[0].platform}/{axes}{topo}"


def plan_key(n: int, dtype, mesh=None, *, fingerprint: Optional[str] = None) -> str:
    """(size-bucket, dtype, mesh fingerprint) -> plan-cache key.

    ``fingerprint=`` substitutes a precomputed mesh fingerprint — how
    tooling builds keys for a topology the current process is not part of
    (e.g. a coordinator inspecting a multi-host plan file).

    >>> plan_key(3000, jnp.int32) == plan_key(4096, jnp.int32)  # same bucket
    True
    >>> plan_key(4096, jnp.int32) == plan_key(4097, jnp.int32)  # next bucket
    False
    >>> plan_key(100, jnp.int32, fingerprint="cpu/x=4/procs2x2")
    '128|int32|cpu/x=4/procs2x2'
    """
    fp = mesh_fingerprint(mesh) if fingerprint is None else fingerprint
    return f"{next_pow2(n)}|{jnp.dtype(dtype).name}|{fp}"


def parse_plan_key(key: str):
    """Inverse of ``plan_key``: ``(size_bucket, dtype_name, fingerprint)``.

    Round-trips every sort-cell key, including multi-process fingerprints
    (property-tested in tests/test_plan_cache_concurrency.py).  Non-sort
    cells (the MoE ``moe/E<e>k<k>|...`` keys) raise ``ValueError`` — they
    carry extra fields and are parsed by their own consumer.

    >>> parse_plan_key(plan_key(3000, jnp.int32, fingerprint="cpu/x=8"))
    (4096, 'int32', 'cpu/x=8')
    """
    parts = key.split("|")
    if len(parts) != 3 or not parts[0].isdigit():
        raise ValueError(f"not a sort plan-cache key: {key!r}")
    bucket, dtype_name, fp = parts
    return int(bucket), dtype_name, fp


def plan_from_strategy(strategy: str, *, n_threads: int = 8) -> SortPlan:
    """Map the public api.py strategy names onto plans (back-compat).

    >>> plan_from_strategy("shared_merge").local_impl
    'merge'
    >>> plan_from_strategy("shared").strategy
    'shared'
    """
    table = {
        "shared": SortPlan("shared", local_impl="xla", n_threads=n_threads),
        "shared_merge": SortPlan("shared", local_impl="merge", n_threads=n_threads),
        "shared_hybrid": SortPlan("shared", local_impl="xla", n_threads=n_threads),
        "distributed_merge": SortPlan("distributed_merge"),
        "cluster": SortPlan("cluster"),
    }
    if strategy not in table:
        raise ValueError(f"strategy must be one of {tuple(table)}")
    return table[strategy]


def default_plan(mesh=None) -> SortPlan:
    """The pre-autotune rule (what api.sort hard-coded before the engine)."""
    return SortPlan("cluster") if mesh is not None else SortPlan("shared")


def run_plan(
    plan: SortPlan,
    x: jax.Array,
    *,
    mesh=None,
    axis: Optional[str] = None,
    ascending: bool = True,
    **kwargs,
):
    """Execute a plan. Cluster plans return (slab, valid) like cluster_sort.

    >>> [int(v) for v in run_plan(SortPlan("shared"), jnp.array([3, 1, 2]))]
    [1, 2, 3]
    """
    if not ascending and plan.strategy == "cluster":
        raise ValueError(
            "the cluster strategy sorts ascending only; for descending "
            "distributed sorts use repro.engine.sort_kv(ascending=False)"
        )
    if plan.strategy == "shared":
        return shared_memory_sort(
            x,
            n_threads=plan.n_threads,
            local_impl=plan.local_impl,
            ascending=ascending,
            block_n=plan.block_n,
        )
    if mesh is None or axis is None:
        raise ValueError(f"plan strategy {plan.strategy!r} requires mesh= and axis=")
    if plan.strategy == "distributed_merge":
        kwargs.setdefault("local_impl", plan.local_impl)
        kwargs.setdefault("block_n", plan.block_n)
        out = distributed_merge_sort(x, mesh, axis, **kwargs)
        return out if ascending else jnp.flip(out, -1)
    if plan.strategy == "cluster":
        kwargs.setdefault("local_impl", plan.local_impl)
        kwargs.setdefault("block_n", plan.block_n)
        # partitioner_mode folds the plan's partition override in: a plan
        # promoted to the sample partition executes sample splitters even
        # though its tuned mode is still the radix one it was swept at
        kwargs.setdefault("mode", plan.partitioner_mode())
        kwargs.setdefault("capacity_factor", plan.capacity_factor)
        return cluster_sort(x, mesh, axis, **kwargs)
    raise ValueError(f"unknown plan strategy {plan.strategy!r}")


def _time_plan_reps(plan, x, mesh, axis, *, reps: int, **kwargs) -> list:
    """Per-rep wall-clock timings (microseconds) after one warmup call.

    Each rep blocks individually so the list supports order statistics —
    the distributed sweep wants the *median* rep (robust to one gloo
    hiccup), while the single-process sweep keeps the historical mean.
    """
    out = run_plan(plan, x, mesh=mesh, axis=axis, **kwargs)
    jax.block_until_ready(out)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = run_plan(plan, x, mesh=mesh, axis=axis, **kwargs)
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) * 1e6)
    return times


def _time_plan(plan, x, mesh, axis, *, reps: int, **kwargs) -> float:
    times = _time_plan_reps(plan, x, mesh, axis, reps=reps, **kwargs)
    return sum(times) / len(times)


def _median(xs) -> float:
    s = sorted(xs)
    k = len(s) // 2
    return s[k] if len(s) % 2 else 0.5 * (s[k - 1] + s[k])


# ------------------------------------------------ distributed coordination ---
# A rank-coordinated sweep needs three collectives the single-process planner
# never had: a barrier so every rank times the same candidate over the same
# quiet wire, a max-over-ranks reduction so every rank scores a candidate by
# its *slowest* participant (the number that actually bounds a distributed
# sort), and a broadcast so the winner every rank proceeds with is rank 0's
# pick by construction, not N locally-identical argmins trusted to agree.

def _dist_barrier(tag: str) -> None:
    from jax.experimental import multihost_utils

    multihost_utils.sync_global_devices(tag)


def _max_over_ranks(value: float) -> float:
    """Reduce one per-rank scalar to its max across all processes.

    Every rank must call this (it is a collective); a rank whose candidate
    failed contributes ``inf``, which poisons the candidate everywhere —
    a plan only some ranks can run is not a plan.
    """
    import numpy as np
    from jax.experimental import multihost_utils

    got = np.asarray(
        multihost_utils.process_allgather(np.asarray(value, np.float64))
    )
    return float(np.max(got))


# the fixed wire size for the winning-plan broadcast: collectives need every
# rank to contribute identical shapes, so rank 0's JSON is padded to this
_PLAN_WIRE_BYTES = 4096


def _broadcast_plan(plan: Optional["SortPlan"]) -> "SortPlan":
    """Broadcast rank 0's winning plan to every rank (collective).

    Serialized as zero-padded JSON in a fixed-size uint8 buffer (JSON never
    contains NUL, so stripping the padding is unambiguous).  Non-zero ranks'
    ``plan`` argument is ignored — the return value is authoritative.
    """
    import numpy as np
    from jax.experimental import multihost_utils

    buf = np.zeros(_PLAN_WIRE_BYTES, np.uint8)
    if jax.process_index() == 0:
        if plan is None:
            raise RuntimeError("rank 0 has no winning plan to broadcast")
        payload = json.dumps(plan.to_dict()).encode()
        if len(payload) > _PLAN_WIRE_BYTES:
            raise ValueError(f"plan JSON exceeds {_PLAN_WIRE_BYTES} bytes")
        buf[: len(payload)] = np.frombuffer(payload, np.uint8)
    # allgather rather than broadcast_one_to_all: the gather keeps each
    # rank's buffer byte-exact as its own row, and every rank decodes the
    # same authoritative row 0 — still one agreement collective.
    rows = np.asarray(multihost_utils.process_allgather(buf))
    out = rows[0] if rows.ndim == 2 else rows
    return SortPlan.from_dict(json.loads(bytes(out).rstrip(b"\x00").decode()))


PALLAS_BLOCK_SWEEP = (256, 512, 1024)

# Off-TPU the Pallas kernels run in interpret mode, which is a correctness
# path, not a perf path — timing it on multi-million-key buckets would stall
# an autotune sweep for minutes to learn nothing. Cells above this size only
# sweep pallas candidates on a real TPU backend.
PALLAS_INTERPRET_MAX = 1 << 16


def candidate_plans(mesh=None, *, quick: bool = False):
    """The tuning grid: strategies x local_impl (x capacity for model D).

    ``local_impl='pallas'`` enters the sweep with one candidate per tile
    width in ``PALLAS_BLOCK_SWEEP`` (quick mode: just the smallest), so the
    tuned plan pins the ``block_n`` that measured fastest for its cell.
    """
    impls = ("xla", "merge") if quick else tuple(i for i in LOCAL_SORTS if i != "pallas")
    cands = [SortPlan("shared", local_impl=i) for i in impls]
    blocks = PALLAS_BLOCK_SWEEP[:1] if quick else PALLAS_BLOCK_SWEEP
    cands += [SortPlan("shared", local_impl="pallas", block_n=b) for b in blocks]
    if mesh is not None:
        cands += [SortPlan("distributed_merge", local_impl="xla")]
        cfs = (2.0,) if quick else (1.5, 2.0)
        # sweep the partition policy too: the composite-splitter sample mode
        # and (full sweeps only) the auto-ranged radix mode compete with the
        # historic plain-splitters mode on the measured workload
        modes = ("splitters", "sample") if quick else ("splitters", "sample", "radix")
        cands += [
            SortPlan("cluster", local_impl="xla", capacity_factor=cf, mode=md)
            for cf in cfs
            for md in modes
        ]
    return cands


class Planner:
    """Plan table: lookup tuned plans, autotune missing cells, persist JSON.

    Beyond the tuned-plan table, the planner closes the capacity-learning
    loop (repro.engine.adapt): ``recorder`` hands ``cluster_sort`` /
    ``cluster_sort_kv`` a telemetry callback bound to a plan-cache key,
    ``observe_exchange`` folds each observation into a learned per-key
    ``capacity_factor``, and ``plan_for`` serves cluster plans with the
    learned factor applied — persisted through the JSON plan cache so the
    lesson survives restarts.

    >>> Planner().plan_for(1000, jnp.int32).strategy   # untuned: default rule
    'shared'
    """

    def __init__(
        self, path: Optional[str] = None, *, learned_scope: Optional[str] = None
    ):
        scope = learned_scope or os.environ.get("REPRO_LEARNED_SCOPE", "global")
        if scope not in LEARNED_SCOPES:
            raise ValueError(f"learned_scope must be one of {LEARNED_SCOPES}")
        self.path = path
        self.learned_scope = scope
        self.plans: Dict[str, SortPlan] = {}
        self.telemetry = ExchangeTelemetry()
        self.learner = CapacityLearner()
        self.learned: Dict[str, LearnedCapacity] = {}
        # services register their stats here so overflow retries/recompiles
        # observed on the exchange path surface in serving telemetry
        self._stats_sinks: list = []
        self._lock = threading.Lock()
        if path and os.path.exists(path):
            self.load(path)

    # ------------------------------------------------------------ storage ---
    @staticmethod
    def _parse_doc(doc) -> tuple:
        """Validate one plan-cache JSON document -> (plans, learned).

        Raises on anything malformed; graceful-degradation policy lives in
        the callers (``load`` warns and keeps state, ``save`` merges from
        nothing).
        """
        if doc.get("version") not in _LOADABLE_VERSIONS:
            raise ValueError(f"plan cache version {doc.get('version')!r} unsupported")
        raw = doc["plans"]
        if not isinstance(raw, dict):
            raise ValueError("'plans' must be an object")
        plans = {}
        for k, v in raw.items():
            if not isinstance(v, dict):
                raise ValueError(f"plan entry {k!r} is not an object")
            plan = SortPlan.from_dict(v)  # unknown fields: forward-compat
            if plan.strategy not in _PLAN_STRATEGIES:
                raise ValueError(
                    f"plan entry {k!r} has unknown strategy {plan.strategy!r}"
                )
            if plan.partition is not None and plan.partition not in PARTITION_MODES:
                raise ValueError(
                    f"plan entry {k!r} has unknown partition {plan.partition!r}"
                )
            plans[k] = plan
        raw_learned = doc.get("learned", {})  # absent in v1 files
        if not isinstance(raw_learned, dict):
            raise ValueError("'learned' must be an object")
        learned = {}
        for k, v in raw_learned.items():
            if not isinstance(v, dict) or "capacity_factor" not in v:
                raise ValueError(f"learned entry {k!r} is malformed")
            learned[k] = LearnedCapacity.from_dict(v)
        return plans, learned

    @staticmethod
    def _merge_learned(
        mine: Dict[str, LearnedCapacity], theirs: Dict[str, LearnedCapacity]
    ) -> Dict[str, LearnedCapacity]:
        """Union two learned tables; shared keys merge via
        ``LearnedCapacity.merge`` (more-informed lineage wins — commutative
        and idempotent, so any interleaving of concurrent writers converges
        to the same table)."""
        out = dict(theirs)
        for k, entry in mine.items():
            other = out.get(k)
            out[k] = entry.merge(other) if other is not None else entry
        return out

    def load(self, path: str, *, strict: bool = False) -> "Planner":
        """Load a plan-cache file; a serving process must never die because a
        tuned-plans file rotted on disk.  Corrupt/truncated JSON, an unknown
        version, or malformed plan entries warn and keep the **current**
        table — empty at construction (every lookup then uses
        ``default_plan``), or the last-known-good plans when a live process
        re-loads a file that rotted mid-write.  Pass ``strict=True`` to
        re-raise instead (tooling that writes the file).

        The ``learned`` section **merges** into in-memory state instead of
        replacing it (field-wise max per shared key): a live rank re-reading
        a shared ``$REPRO_SORT_PLANS`` file picks up what other ranks
        learned without discarding its own observations.  The ``plans``
        table keeps replace semantics — the file is the tuning authority.
        """
        try:
            with open(path) as f:
                doc = json.load(f)
            plans, learned = self._parse_doc(doc)
        except Exception as e:
            if strict:
                raise
            warnings.warn(
                f"ignoring unreadable plan cache {path!r} ({e}); "
                f"keeping the {len(self.plans)} previously loaded plan(s)",
                RuntimeWarning,
                stacklevel=2,
            )
            return self
        with self._lock:
            self.plans = plans
            self.learned = self._merge_learned(self.learned, learned)
        return self

    def save(self, path: Optional[str] = None) -> str:
        """Persist plans + learned state with concurrent-writer safety.

        The write is a **read-merge-write** under an advisory ``fcntl`` lock
        (``<path>.lock``): re-read the file, union plan keys this planner
        does not carry, merge the on-disk ``learned`` section per key
        (``LearnedCapacity.merge``), then atomically ``os.replace`` the
        result into place.  Two ranks of a ``jax.distributed`` job learning
        capacity factors into one ``$REPRO_SORT_PLANS`` file therefore never
        clobber each other — the surviving file carries both ranks' entries
        no matter how the saves interleave (tests/test_plan_cache_concurrency
        in-process, tests/multihost/ across real processes).
        """
        path = path or self.path
        if path is None:
            raise ValueError("no path given and Planner has no default path")
        # the whole write happens under the thread lock: concurrent
        # telemetry-driven saves in this process serialize here, and the
        # fcntl lock extends the same exclusion across processes
        with self._lock:
            os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
            with _plan_file_lock(path):
                disk_plans: Dict[str, SortPlan] = {}
                disk_learned: Dict[str, LearnedCapacity] = {}
                if os.path.exists(path):
                    try:
                        with open(path) as f:
                            disk_plans, disk_learned = self._parse_doc(json.load(f))
                    except Exception:
                        # a rotted file must not block persisting fresh state;
                        # there is nothing trustworthy in it to preserve
                        disk_plans, disk_learned = {}, {}
                plans = {**disk_plans, **self.plans}  # ours win shared keys
                learned = self._merge_learned(self.learned, disk_learned)
                doc = {
                    "version": _PLAN_VERSION,
                    "plans": {k: p.to_dict() for k, p in sorted(plans.items())},
                    "learned": {
                        k: c.to_dict() for k, c in sorted(learned.items())
                    },
                }
                # per-pid tmp name: a crashed writer's leftover can never be
                # overwritten mid-rename by another rank on the same host
                tmp = f"{path}.tmp.{os.getpid()}"
                with open(tmp, "w") as f:
                    json.dump(doc, f, indent=1)
                os.replace(tmp, path)
            self.path = self.path or path
        return path

    # ------------------------------------------------------------- lookup ---
    def lookup(self, n: int, dtype, mesh=None) -> Optional[SortPlan]:
        return self.plans.get(plan_key(n, dtype, mesh))

    def warmup_cells(self, mesh=None):
        """The (size_bucket, dtype name) cells this plan table names for the
        given hardware fingerprint — the enumeration AOT warmup compiles
        ahead of traffic (``repro.engine.frontend.warmup``).

        Both the tuned ``plans`` table and the ``learned`` capacity section
        contribute: a cell with learned state but no tuned plan still proves
        real traffic landed there, and warming it is exactly as valuable.
        Non-sort keys (the MoE dispatch cells, ``moe/E<e>k<k>|...``) are not
        executable-cache cells and are skipped.

        >>> p = Planner()
        >>> p.plans["4096|int32|" + mesh_fingerprint()] = SortPlan("shared")
        >>> p.plans["moe/E8k2|256|float32|" + mesh_fingerprint()] = SortPlan()
        >>> p.warmup_cells()
        [(4096, 'int32')]
        """
        fp = mesh_fingerprint(mesh)
        my_suffix = f"@h{jax.process_index()}"  # per_host-scoped learned keys
        cells = set()
        for key in list(self.plans) + list(self.learned):
            if key.endswith(my_suffix):
                key = key[: -len(my_suffix)]  # this host's cells warm here;
            parts = key.split("|")  # other hosts' fail the fp match below
            if len(parts) != 3 or not parts[0].isdigit():
                continue  # MoE dispatch cells and future non-sort keys
            bucket, dtype_name, key_fp = parts
            if key_fp == fp:
                cells.add((int(bucket), dtype_name))
        return sorted(cells)

    def plan_for(self, n: int, dtype, mesh=None) -> SortPlan:
        """Tuned plan if one exists, else the pre-engine default rule — with
        the learned capacity factor folded into cluster plans (so
        steady-state callers size model-D slabs right on their first
        compile) and the skew-promotion latch applied: a radix-family plan
        whose cell the learner promoted comes back with
        ``partition="sample"``."""
        plan = self.lookup(n, dtype, mesh) or default_plan(mesh)
        if plan.strategy == "cluster":
            key = plan_key(n, dtype, mesh)
            promoted, _ = self.promotion_state(key)
            if promoted == "sample" and plan.effective_partition() == "radix":
                plan = replace(plan, partition="sample")
            cf = self.capacity_factor_for(key, default=plan.capacity_factor)
            if cf != plan.capacity_factor:
                plan = replace(plan, capacity_factor=cf)
        return plan

    # -------------------------------------------------- capacity learning ---
    def scoped_key(self, key: str) -> str:
        """Apply the learned-factor scope policy to a plan-cache key.

        ``global`` scope (default) returns the key unchanged: every rank of
        a multi-process job reads and merges one shared entry, so the most
        conservative rank's factor wins — right when skew follows the
        *data*, which any rank may receive.  ``per_host`` scope suffixes
        ``@h<process_index>``: each host learns its own factor — right when
        skew follows the *host* (a shard pinned to hot keys), where one hot
        host must not inflate every host's slab memory.  Both read and
        write paths (``capacity_factor_for`` / ``observe_exchange``) apply
        the same scoping, so a planner always reads what it wrote.
        """
        if self.learned_scope == "per_host":
            return f"{key}@h{jax.process_index()}"
        return key

    def capacity_factor_for(self, key: str, default: float = 2.0) -> float:
        """The learned capacity factor for a plan-cache key (``default``
        until telemetry for that key has taught us otherwise)."""
        key = self.scoped_key(key)
        with self._lock:
            entry = self.learned.get(key)
        return entry.capacity_factor if entry is not None else default

    def promotion_state(self, key: str) -> tuple:
        """``(partition, skew_strikes)`` of a key's learned entry — the
        skew-promotion latch, observable without touching private state.
        ``(None, 0)`` until the key has radix-skew history; ``("sample", _)``
        once promotion latched (the scope policy is applied, so a caller
        always reads the entry its own observations feed)."""
        key = self.scoped_key(key)
        with self._lock:
            entry = self.learned.get(key)
        if entry is None:
            return (None, 0)
        return (entry.partition, entry.skew_strikes)

    # persistence debounce: a learned-factor move below this fraction of the
    # default stays in memory only — skew that fluctuates call-to-call must
    # not turn the sort hot path into a full-file rewrite per call
    _SAVE_REL_DELTA = 0.05

    def observe_exchange(
        self, key: str, obs: ExchangeObservation, *, default: float = 2.0
    ) -> LearnedCapacity:
        """Fold one exchange observation into the learned table (and the
        telemetry ledger).  Persists when the planner has a backing file and
        the learned factor moved *materially* (>= ``_SAVE_REL_DELTA`` of the
        default, or landed exactly back on it) — steady state costs zero
        writes, and jittery skew costs only in-memory updates."""
        key = self.scoped_key(key)
        self.telemetry.record(key, obs)
        with self._lock:
            prev = self.learned.get(key)
            prev_cf = prev.capacity_factor if prev else default
            cf = self.learner.update(prev_cf, obs, default=default)
            prev_part = prev.partition if prev else None
            strikes = self.learner.promotion_strikes(
                prev.skew_strikes if prev else 0, obs
            )
            part = prev_part
            calm = prev.calm_streak if prev else 0
            demotions = prev.demotions if prev else 0
            if part != "sample" and self.learner.should_promote(strikes):
                part = "sample"  # the latch: merge keeps it within this
                calm = 0  # generation — only the probation below can undo it
            elif part == "sample":
                # promoted cell on probation: long calm stretches demote it
                # back to the radix family, one generation up so concurrent
                # writers holding the stale promotion can't flap it back
                calm = self.learner.calm_streak(calm, obs)
                if self.learner.should_demote(calm, demotions):
                    part, strikes, calm = None, 0, 0
                    demotions += 1
            entry = LearnedCapacity(
                capacity_factor=cf,
                peak_factor=max(
                    prev.peak_factor if prev else 0.0, obs.required_factor()
                ),
                observations=(prev.observations if prev else 0) + 1,
                partition=part,
                skew_strikes=strikes,
                calm_streak=calm,
                demotions=demotions,
            )
            self.learned[key] = entry
            changed = part != prev_part or (
                cf != prev_cf
                and (
                    abs(cf - prev_cf) >= self._SAVE_REL_DELTA * default
                    or cf == default  # the decay's landing point: worth a write
                )
            )
            self._stats_sinks = [r for r in self._stats_sinks if r() is not None]
            sinks = list(self._stats_sinks)
        for ref in sinks:
            svc = ref()
            if svc is not None:
                svc._note_exchange(obs)
        if changed and self.path:
            self.save()
        return entry

    def exchange_recorder(self, key: str, *, default: float = 2.0):
        """A telemetry callback bound to this planner and an arbitrary
        plan-cache key.  Sort cells use ``(n, dtype, mesh)`` keys via
        ``recorder``; the MoE dispatch path binds its own
        ``moe/E<experts>k<top_k>|...`` keys (``models.moe.moe_plan_key``) —
        one learned table, many exchange consumers."""

        def record(**kwargs) -> None:
            self.observe_exchange(key, ExchangeObservation(**kwargs), default=default)

        return record

    def recorder(self, n: int, dtype, mesh=None, *, default: float = 2.0):
        """A telemetry callback for ``cluster_sort(telemetry=...)`` bound to
        this planner and the (n, dtype, mesh) plan-cache key — the glue that
        closes the capacity-learning loop."""
        return self.exchange_recorder(plan_key(n, dtype, mesh), default=default)

    def cluster_kwargs(
        self,
        n: int,
        dtype,
        mesh=None,
        *,
        default: Optional[float] = None,
        mode: Optional[str] = None,
    ) -> dict:
        """The ``capacity_factor=`` / ``telemetry=`` kwargs that close the
        capacity-learning loop for one cluster call — the one policy both
        ``repro.sort`` and ``engine.sort_kv`` apply (only when the caller
        passed neither kwarg: an explicit value opts the call out of the
        whole loop, reading and writing).  ``default`` is the learner's
        floor; when omitted, a tuned cluster plan's own factor (if any) is
        used so a cell that won at a lean factor is never re-inflated.

        ``mode`` is a *hint*, not a request: pass the partitioner mode the
        caller will run (or None if the caller uses the default).  When the
        caller has no explicit mode and this cell's learned entry carries
        the skew-promotion latch, the returned dict additionally includes
        ``"mode": "sample"`` — and the learner floor drops to
        ``SAMPLE_DEFAULT_FACTOR`` so the capacity factor the radix era
        inflated decays back toward ~1.  A caller-chosen mode is always
        respected (no key collision, no silent override)."""
        if default is None:
            base = self.lookup(n, dtype, mesh)
            default = (
                base.capacity_factor
                if base is not None and base.strategy == "cluster"
                else SortPlan.capacity_factor
            )
        key = plan_key(n, dtype, mesh)
        out = {}
        if mode is None:
            promoted, _ = self.promotion_state(key)
            if promoted == "sample":
                out["mode"] = "sample"
                default = min(default, SAMPLE_DEFAULT_FACTOR)
        out["capacity_factor"] = self.capacity_factor_for(key, default=default)
        out["telemetry"] = self.recorder(n, dtype, mesh, default=default)
        return out

    def add_stats_sink(self, service) -> None:
        """Register a service whose stats should see exchange retry/recompile
        counts (held weakly; dead services are dropped on the next observe)."""
        with self._lock:
            self._stats_sinks.append(weakref.ref(service))

    # ----------------------------------------------------------- autotune ---
    # observability for the single-writer election: True iff the *last*
    # autotune call on this planner persisted the plan file from this
    # process (rank 0 in a distributed sweep; any rank single-process)
    last_autotune_wrote: bool = False

    def autotune(
        self,
        n: int,
        dtype=jnp.int32,
        *,
        mesh=None,
        axis: Optional[str] = None,
        reps: int = 3,
        quick: bool = False,
        seed: int = 0,
        save: bool = True,
        distributed: Optional[bool] = None,
        candidates=None,
        on_candidate=None,
        **kwargs,
    ) -> SortPlan:
        """Microbenchmark every candidate on synthetic keys; persist winner.

        Timed at the size bucket (next pow2 of ``n``) so every n in the bucket
        shares the plan — the same bucketing the compiled-executable cache
        uses, keeping plan granularity == compilation granularity.

        **Distributed sweeps.**  Under multi-process ``jax.distributed``
        (``distributed=None`` auto-detects ``jax.process_count() > 1``;
        pass ``False`` to opt a rank-divergent caller out) the sweep is
        rank-coordinated: a barrier precedes each candidate so every rank
        times it over a quiet wire, each rank scores the candidate by its
        **median** rep (robust to one slow rep), the per-rank scores reduce
        by **max over ranks** (a distributed sort is as slow as its slowest
        participant — and the reduced table is bit-identical everywhere, so
        every rank computes the same argmin), rank 0's winner is broadcast
        to all ranks as an explicit agreement step, and **rank 0 alone**
        writes the plan file through the fcntl-locked merge-on-save path —
        a final barrier holds the other ranks until the file is on disk.
        The cell lands under the ``/procs<P>x<D>`` fingerprint, so it never
        masquerades as a single-host plan.  ``last_autotune_wrote`` records
        which process performed the save.

        ``candidates=`` substitutes an explicit plan list for the default
        grid (how tests and smoke jobs keep a sweep tiny); ``on_candidate``
        is called as ``on_candidate(i, plan)`` before each candidate is
        timed — the multihost fault-injection battery hooks rank crashes
        and hangs there.
        """
        import numpy as np

        if distributed is None:
            distributed = jax.process_count() > 1
        nb = next_pow2(n)
        x = jnp.asarray(
            np.random.default_rng(seed).integers(100, 1000, size=nb).astype("int64"),
            jnp.dtype(dtype),
        )
        x_mesh = x
        if mesh is not None:
            P_ = mesh.shape[axis]
            if nb % P_:
                raise ValueError(
                    f"axis size {P_} must divide the size bucket {nb}"
                )
            if distributed:
                # multi-process meshes need committed global arrays; the
                # single-process forced mesh auto-shards host-local ones
                from jax.sharding import NamedSharding, PartitionSpec

                x_mesh = jax.device_put(
                    x, NamedSharding(mesh, PartitionSpec(axis))
                )
        key = plan_key(nb, dtype, mesh)
        cands = (
            candidate_plans(mesh, quick=quick)
            if candidates is None
            else list(candidates)
        )
        interpret_backend = jax.default_backend() != "tpu"
        best = None
        for i, cand in enumerate(cands):
            if (
                interpret_backend
                and cand.local_impl == "pallas"
                and nb > PALLAS_INTERPRET_MAX
            ):
                continue  # interpret-mode kernels: correctness path, not timeable
            if on_candidate is not None:
                on_candidate(i, cand)
            if distributed:
                _dist_barrier(f"autotune:{key}:{i}")
            arr = x if cand.strategy == "shared" else x_mesh
            try:
                times = _time_plan_reps(cand, arr, mesh, axis, reps=reps, **kwargs)
                us = _median(times) if distributed else sum(times) / len(times)
            except Exception:
                if cand.local_impl != "pallas":
                    raise
                # a pallas tile the local Mosaic/backend can't lower is a
                # skipped candidate, not a failed sweep — but a distributed
                # rank still owes the reduction its (poisoned) score
                if not distributed:
                    continue
                us = float("inf")
            if distributed:
                us = _max_over_ranks(us)
                if us == float("inf"):
                    continue
            cand = replace(cand, us_per_call=round(us, 2))
            if best is None or cand.us_per_call < best.us_per_call:
                best = cand
        if best is None:
            raise RuntimeError(f"autotune: no timeable candidate for {key}")
        if distributed:
            # every rank already holds the same argmin (the reduced table is
            # identical), but agreement is asserted, not assumed: rank 0's
            # pick is what everyone proceeds with, bit for bit
            best = _broadcast_plan(best)
        self.plans[key] = best
        self.last_autotune_wrote = False
        if save and self.path:
            if not distributed or jax.process_index() == 0:
                self.save()
                self.last_autotune_wrote = True
            if distributed:
                # hold every rank until the winner is on disk: a rank that
                # re-loads the shared file right after autotune must see it
                _dist_barrier(f"autotune:{key}:saved")
        return best


_DEFAULT: Optional[Planner] = None


def default_planner() -> Planner:
    """Process-wide planner; honours $REPRO_SORT_PLANS as its backing file.

    >>> default_planner() is default_planner()   # one table per process
    True
    """
    global _DEFAULT
    if _DEFAULT is None:
        _DEFAULT = Planner(os.environ.get("REPRO_SORT_PLANS"))
    return _DEFAULT


def autotune(n: int, dtype=jnp.int32, **kwargs) -> SortPlan:
    """Module-level convenience: autotune into the default planner.

    >>> autotune(64, reps=1, quick=True, save=False).strategy
    'shared'
    """
    return default_planner().autotune(n, dtype, **kwargs)
