"""Batched sort front door: ragged requests in, one vmapped sort per bucket.

``SortService.submit`` accepts a ragged batch of 1-D requests, groups them by
(length bucket, dtype), pads each group to a (pow2 batch, pow2 length) block
in numpy, and runs one ahead-of-time compiled executable per block shape from
the ``CompiledCache``.  All padding/slicing stays in numpy so the steady-state
hot path performs **zero** jax tracing/lowering — the property the engine
tests assert with jax's compilation counters.

The group/pad/execute core lives in ``_run_group`` so the sync ``submit``
path and the async micro-batching queue (``repro.engine.queue``) share one
implementation — the queue coalesces requests *across* callers into the same
per-(bucket, dtype, kind) groups this module executes.

Plans come from the ``Planner``: the per-bucket local sort recipe is the
tuned shared-memory plan for that (bucket, dtype) cell (a serving front door
is a single-host component; cluster plans apply to the mesh path in kv.py).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.shared_sort import shared_memory_sort
from .cache import CompiledCache, size_bucket
from .kv import _gather_last, _order_keys
from .planner import Planner, SortPlan, default_planner

__all__ = ["SortService", "ServiceStats"]

_KINDS = ("sort", "argsort", "sort_kv")


@dataclass
class ServiceStats:
    """Rolling counters for one ``SortService`` (requests, padding, compiles).

    ``elapsed_s`` is *busy* wall time: the union of the per-batch execution
    spans, with overlaps between concurrent submitters merged — so
    ``throughput_keys_per_s`` stays meaningful (and ``elapsed_s`` never
    exceeds real wall time) no matter how many threads submit at once.

    ``overflow_retries`` / ``recompiles`` count model-D slab overflows (and
    the fresh executables those overflows forced) observed by this service's
    *planner* on the exchange path — previously this telemetry silently
    vanished; now it rides the same ledger ``serve.py --stats`` prints.
    They mirror planner-wide telemetry: every service sharing a planner (the
    process-wide default, usually) sees the same counts, so read them as
    "what the planner saw", not a per-service sum.  ``peak_mean_ratio`` is
    the largest peak/mean bucket-load ratio any observed exchange reported —
    the skew signal radix->sample promotion decisions read; ~1.0 means
    balanced partitions, values past the learner's ``promote_ratio`` mean
    promotion is (or soon will be) in play.

    >>> ServiceStats(keys_in=100, elapsed_s=2.0).throughput_keys_per_s()
    50.0
    """

    requests: int = 0
    batches: int = 0
    keys_in: int = 0
    padded_keys: int = 0
    elapsed_s: float = 0.0
    compiles: int = 0
    cache_hits: int = 0
    overflow_retries: int = 0
    recompiles: int = 0
    peak_mean_ratio: float = 0.0
    _busy_until: float = field(default=0.0, repr=False, compare=False)

    def throughput_keys_per_s(self) -> float:
        return self.keys_in / self.elapsed_s if self.elapsed_s else 0.0

    def account_span(self, t0: float, t1: float) -> None:
        """Merge one batch's [t0, t1] execution span into the busy time.

        Overlapping spans (concurrent submitters) only count once — the
        accounting is the union of intervals, not their sum.

        >>> s = ServiceStats()
        >>> s.account_span(0.0, 1.0); s.account_span(0.5, 1.5)  # overlap
        >>> s.elapsed_s
        1.5
        """
        self.elapsed_s += max(0.0, t1 - max(t0, self._busy_until))
        self._busy_until = max(self._busy_until, t1)


def _np_sentinel(dtype: np.dtype, *, largest: bool):
    if np.issubdtype(dtype, np.floating):
        return np.inf if largest else -np.inf
    info = np.iinfo(dtype)
    return info.max if largest else info.min


class SortService:
    """Shape-bucketed, plan-driven batch sorter with recompile accounting.

    >>> import numpy as np
    >>> svc = SortService()
    >>> [out] = svc.submit([np.array([3, 1, 2], np.int32)])
    >>> [int(v) for v in out]
    [1, 2, 3]
    >>> svc.stats.requests
    1
    """

    def __init__(
        self,
        *,
        planner: Optional[Planner] = None,
        min_bucket: int = 8,
    ):
        self.planner = planner or default_planner()
        self.min_bucket = min_bucket
        self.cache = CompiledCache()
        self.stats = ServiceStats()
        # guards cache lookups/compiles and stats counters; the executable
        # call itself runs outside it so concurrent batches still overlap
        self._lock = threading.Lock()
        # overflow retries/recompiles the planner observes on the exchange
        # path land in this service's stats instead of vanishing
        self.planner.add_stats_sink(self)

    def _note_exchange(self, obs) -> None:
        """Planner stats-sink hook: fold one exchange observation's retry and
        recompile cost — and its peak/mean bucket ratio — into this
        service's ledger."""
        with self._lock:
            self.stats.overflow_retries += obs.retries
            self.stats.recompiles += obs.recompiles
            self.stats.peak_mean_ratio = max(
                self.stats.peak_mean_ratio, obs.peak_mean_ratio()
            )

    # ------------------------------------------------------------ builders ---
    @staticmethod
    def _plan_fields(kind: str, plan: SortPlan):
        """The (impl, block_n, n_threads) that actually shape ``kind``'s
        program — the executable-cache key uses exactly these, so plans that
        differ only in fields this kind ignores share one executable."""
        impl = plan.local_impl
        if kind != "sort" and impl != "pallas":
            impl = "xla"  # argsort kinds only have the xla/pallas engines
        block_n = plan.block_n if impl == "pallas" else None
        n_threads = plan.n_threads if kind == "sort" else 0
        return impl, block_n, n_threads

    def _builder(self, kind: str, plan: SortPlan, ascending: bool):
        impl, block_n, n_threads = self._plan_fields(kind, plan)
        if kind == "sort":
            def build():
                return lambda xb: shared_memory_sort(
                    xb,
                    n_threads=n_threads,
                    local_impl=impl,
                    ascending=ascending,
                    block_n=block_n,
                )
        elif kind == "argsort":
            def build():
                return lambda xb: _order_keys(
                    xb, ascending=ascending, impl=impl, block_n=block_n
                )
        else:  # sort_kv
            def build():
                def f(xb, vb):
                    order = _order_keys(
                        xb, ascending=ascending, impl=impl, block_n=block_n
                    )
                    return _gather_last(xb, order), _gather_last(vb, order)
                return f
        return build

    # ---------------------------------------------------------- validation ---
    @staticmethod
    def _validate(
        kind: str,
        requests: Sequence[np.ndarray],
        values: Optional[Sequence[np.ndarray]],
    ) -> Tuple[List[np.ndarray], Optional[List[np.ndarray]]]:
        """Check one ragged batch; returns (reqs, vals) as numpy arrays."""
        if kind not in _KINDS:
            raise ValueError(f"kind must be one of {_KINDS}")
        if (values is not None) != (kind == "sort_kv"):
            raise ValueError("values= is required iff kind='sort_kv'")
        reqs = [np.asarray(r) for r in requests]
        vals = None
        for i, r in enumerate(reqs):
            if r.ndim != 1:
                raise ValueError("requests must be 1-D arrays")
            if np.issubdtype(r.dtype, np.floating) and np.isnan(r).any():
                # NaN sorts after the padding sentinel, which would leak
                # padding values (or out-of-range argsort indices) into results
                raise ValueError(f"request {i} contains NaN keys (unsupported)")
        if kind == "sort_kv":
            vals = [np.asarray(v) for v in values]
            if len(vals) != len(reqs):
                raise ValueError("need exactly one values array per request")
            for i, (r, v) in enumerate(zip(reqs, vals)):
                if v.shape[:1] != r.shape:
                    raise ValueError(f"values[{i}] length must match request {i}")
        return reqs, vals

    def _group_key(self, req: np.ndarray, val: Optional[np.ndarray] = None) -> tuple:
        """(length bucket, dtype[, value signature]) — requests sharing this
        key pad into one batch and run one executable."""
        gk = (size_bucket(len(req), min_bucket=self.min_bucket), req.dtype.name)
        if val is not None:
            gk += (val.shape[1:], val.dtype.name)
        return gk

    def _signature(self, kind: str, gk: tuple, bb: int, ascending: bool):
        """The full executable identity of one (group key, batch bucket) cell:
        (plan, cache key, ShapeDtypeStruct args).  ``_run_group`` and
        ``warm_cell`` both derive their compilations from this one function,
        which is what makes AOT warmup airtight — a warmed cell *is* the
        serving cell, not a lookalike."""
        bucket, dtype_name = gk[0], gk[1]
        plan = self.planner.plan_for(bucket, np.dtype(dtype_name))
        if plan.strategy != "shared":  # front door is single-host
            plan = SortPlan("shared")
        # the executable identity is exactly the plan fields this kind
        # consumes (block_n changes the traced program for pallas plans)
        impl, block_n, n_threads = self._plan_fields(kind, plan)
        key = (kind, bucket, bb, dtype_name, ascending,
               impl, n_threads, block_n)
        args = [jax.ShapeDtypeStruct((bb, bucket), jnp.dtype(dtype_name))]
        if kind == "sort_kv":
            vshape, vdtype = gk[2], np.dtype(gk[3])
            key = key + (vshape, vdtype.name)
            args.append(
                jax.ShapeDtypeStruct((bb, bucket) + vshape, jnp.dtype(vdtype))
            )
        return plan, key, args

    def warm_cell(
        self,
        kind: str,
        bucket: int,
        dtype,
        *,
        batch_bucket: int = 1,
        ascending: bool = True,
        values_spec: Optional[Tuple[tuple, Any]] = None,
    ) -> bool:
        """AOT-compile one executable cell before traffic arrives.

        The cell is identified exactly the way serving identifies it —
        (kind, length bucket, batch bucket, dtype, direction, plan fields) —
        so any later request that lands in a warmed cell is a pure cache hit:
        zero jax tracing, first-request latency == steady-state latency.
        Returns True when this call compiled a fresh executable, False when
        the cell was already warm.

        ``values_spec`` (trailing value shape, value dtype) is required
        semantics for ``kind='sort_kv'`` and defaults to scalar int32 values.

        >>> svc = SortService()
        >>> svc.warm_cell("sort", 1024, "int32")
        True
        >>> svc.warm_cell("sort", 1024, "int32")   # already warm
        False
        """
        gk: tuple = (int(bucket), np.dtype(dtype).name)
        if kind == "sort_kv":
            vshape, vdtype = values_spec if values_spec else ((), np.int32)
            gk += (tuple(vshape), np.dtype(vdtype).name)
        elif values_spec is not None:
            raise ValueError("values_spec= only applies to kind='sort_kv'")
        if kind not in _KINDS:
            raise ValueError(f"kind must be one of {_KINDS}")
        plan, key, args = self._signature(kind, gk, int(batch_bucket), ascending)
        with self._lock:
            before = self.cache.misses
            self.cache.get_or_build(key, self._builder(kind, plan, ascending), args)
            fresh = self.cache.misses - before
            self.stats.compiles += fresh
            self.stats.cache_hits += int(fresh == 0)
        return bool(fresh)

    # ----------------------------------------------------------- execution ---
    def _run_group(
        self,
        kind: str,
        gk: tuple,
        reqs: List[np.ndarray],
        vals: Optional[List[np.ndarray]] = None,
        *,
        ascending: bool = True,
    ) -> List[Any]:
        """Pad one group (all ``reqs`` share ``gk``) and run its executable.

        This is the whole hot path — numpy pad, one AOT executable call,
        numpy slice-out — shared verbatim by ``submit`` and the async queue.
        Returns one result per request, in the given order.
        """
        t0 = time.perf_counter()
        bucket, dtype_name = gk[0], gk[1]
        dtype = np.dtype(dtype_name)
        bb = size_bucket(len(reqs), min_bucket=1)  # pow2 batch bucket
        sent = _np_sentinel(dtype, largest=ascending)
        batch = np.full((bb, bucket), sent, dtype)
        for row, r in enumerate(reqs):
            batch[row, : len(r)] = r

        plan, key, args = self._signature(kind, gk, bb, ascending)

        if kind == "sort_kv":
            vshape, vdtype = gk[2], np.dtype(gk[3])
            vbatch = np.zeros((bb, bucket) + vshape, vdtype)
            for row, v in enumerate(vals):
                vbatch[row, : len(v)] = v

        with self._lock:
            before = self.cache.misses
            exe = self.cache.get_or_build(key, self._builder(kind, plan, ascending), args)
            self.stats.compiles += self.cache.misses - before
            self.stats.cache_hits += int(self.cache.misses == before)
            self.stats.batches += 1
            self.stats.padded_keys += bb * bucket - sum(len(r) for r in reqs)

        out: List[Any] = [None] * len(reqs)
        if kind == "sort_kv":
            ks, vres = exe(batch, vbatch)
            ks, vres = np.asarray(ks), np.asarray(vres)
            for row, r in enumerate(reqs):
                n = len(r)
                out[row] = (ks[row, :n], vres[row, :n])
        else:
            res = np.asarray(exe(batch))
            for row, r in enumerate(reqs):
                # sentinel padding sorts last either direction, so the
                # leading n entries (indices < n for argsort) are the answer
                out[row] = res[row, : len(r)]

        t1 = time.perf_counter()
        with self._lock:
            self.stats.requests += len(reqs)
            self.stats.keys_in += sum(len(r) for r in reqs)
            self.stats.account_span(t0, t1)
        return out

    # -------------------------------------------------------------- submit ---
    def submit(
        self,
        requests: Sequence[np.ndarray],
        *,
        kind: str = "sort",
        values: Optional[Sequence[np.ndarray]] = None,
        ascending: bool = True,
    ) -> List[Any]:
        """Sort a ragged batch. Returns per-request numpy results, in order.

        kind='sort'    -> sorted keys
        kind='argsort' -> stable argsort indices
        kind='sort_kv' -> (sorted keys, aligned values); ``values[i]`` must
                          share ``requests[i]``'s length (extra trailing dims ok)
        """
        reqs, vals = self._validate(kind, requests, values)

        # group request indices by (length bucket, dtype) — plus the value
        # signature for sort_kv, so unrelated payload shapes never collide
        groups: Dict[tuple, List[int]] = {}
        for i, r in enumerate(reqs):
            gk = self._group_key(r, vals[i] if vals is not None else None)
            groups.setdefault(gk, []).append(i)

        out: List[Any] = [None] * len(reqs)
        for gk, idxs in sorted(groups.items(), key=lambda kv: repr(kv[0])):
            results = self._run_group(
                kind,
                gk,
                [reqs[i] for i in idxs],
                [vals[i] for i in idxs] if vals is not None else None,
                ascending=ascending,
            )
            for i, res in zip(idxs, results):
                out[i] = res
        return out
