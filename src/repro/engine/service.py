"""Batched sort front door: ragged requests in, one vmapped sort per bucket.

``SortService.submit`` accepts a ragged batch of 1-D requests, groups them by
(length bucket, dtype), pads each group to a (pow2 batch, pow2 length) block
in numpy, and runs one ahead-of-time compiled executable per block shape from
the ``CompiledCache``.  All padding/slicing stays in numpy so the steady-state
hot path performs **zero** jax tracing/lowering — the property the engine
tests assert with jax's compilation counters.

Plans come from the ``Planner``: the per-bucket local sort recipe is the
tuned shared-memory plan for that (bucket, dtype) cell (a serving front door
is a single-host component; cluster plans apply to the mesh path in kv.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.shared_sort import shared_memory_sort
from .cache import CompiledCache, size_bucket
from .kv import _gather_last, _order_keys
from .planner import Planner, SortPlan, default_planner

__all__ = ["SortService", "ServiceStats"]

_KINDS = ("sort", "argsort", "sort_kv")


@dataclass
class ServiceStats:
    """Rolling counters for one ``SortService`` (requests, padding, compiles).

    >>> ServiceStats(keys_in=100, elapsed_s=2.0).throughput_keys_per_s()
    50.0
    """

    requests: int = 0
    batches: int = 0
    keys_in: int = 0
    padded_keys: int = 0
    elapsed_s: float = 0.0
    compiles: int = 0
    cache_hits: int = 0

    def throughput_keys_per_s(self) -> float:
        return self.keys_in / self.elapsed_s if self.elapsed_s else 0.0


def _np_sentinel(dtype: np.dtype, *, largest: bool):
    if np.issubdtype(dtype, np.floating):
        return np.inf if largest else -np.inf
    info = np.iinfo(dtype)
    return info.max if largest else info.min


class SortService:
    """Shape-bucketed, plan-driven batch sorter with recompile accounting.

    >>> import numpy as np
    >>> svc = SortService()
    >>> [out] = svc.submit([np.array([3, 1, 2], np.int32)])
    >>> [int(v) for v in out]
    [1, 2, 3]
    >>> svc.stats.requests
    1
    """

    def __init__(
        self,
        *,
        planner: Optional[Planner] = None,
        min_bucket: int = 8,
    ):
        self.planner = planner or default_planner()
        self.min_bucket = min_bucket
        self.cache = CompiledCache()
        self.stats = ServiceStats()

    # ------------------------------------------------------------ builders ---
    @staticmethod
    def _plan_fields(kind: str, plan: SortPlan):
        """The (impl, block_n, n_threads) that actually shape ``kind``'s
        program — the executable-cache key uses exactly these, so plans that
        differ only in fields this kind ignores share one executable."""
        impl = plan.local_impl
        if kind != "sort" and impl != "pallas":
            impl = "xla"  # argsort kinds only have the xla/pallas engines
        block_n = plan.block_n if impl == "pallas" else None
        n_threads = plan.n_threads if kind == "sort" else 0
        return impl, block_n, n_threads

    def _builder(self, kind: str, plan: SortPlan, ascending: bool):
        impl, block_n, n_threads = self._plan_fields(kind, plan)
        if kind == "sort":
            def build():
                return lambda xb: shared_memory_sort(
                    xb,
                    n_threads=n_threads,
                    local_impl=impl,
                    ascending=ascending,
                    block_n=block_n,
                )
        elif kind == "argsort":
            def build():
                return lambda xb: _order_keys(
                    xb, ascending=ascending, impl=impl, block_n=block_n
                )
        else:  # sort_kv
            def build():
                def f(xb, vb):
                    order = _order_keys(
                        xb, ascending=ascending, impl=impl, block_n=block_n
                    )
                    return _gather_last(xb, order), _gather_last(vb, order)
                return f
        return build

    # -------------------------------------------------------------- submit ---
    def submit(
        self,
        requests: Sequence[np.ndarray],
        *,
        kind: str = "sort",
        values: Optional[Sequence[np.ndarray]] = None,
        ascending: bool = True,
    ) -> List[Any]:
        """Sort a ragged batch. Returns per-request numpy results, in order.

        kind='sort'    -> sorted keys
        kind='argsort' -> stable argsort indices
        kind='sort_kv' -> (sorted keys, aligned values); ``values[i]`` must
                          share ``requests[i]``'s length (extra trailing dims ok)
        """
        if kind not in _KINDS:
            raise ValueError(f"kind must be one of {_KINDS}")
        if (values is not None) != (kind == "sort_kv"):
            raise ValueError("values= is required iff kind='sort_kv'")
        t0 = time.perf_counter()
        reqs = [np.asarray(r) for r in requests]
        vals = None
        for i, r in enumerate(reqs):
            if r.ndim != 1:
                raise ValueError("requests must be 1-D arrays")
            if np.issubdtype(r.dtype, np.floating) and np.isnan(r).any():
                # NaN sorts after the padding sentinel, which would leak
                # padding values (or out-of-range argsort indices) into results
                raise ValueError(f"request {i} contains NaN keys (unsupported)")
        if kind == "sort_kv":
            vals = [np.asarray(v) for v in values]
            if len(vals) != len(reqs):
                raise ValueError("need exactly one values array per request")
            for i, (r, v) in enumerate(zip(reqs, vals)):
                if v.shape[:1] != r.shape:
                    raise ValueError(f"values[{i}] length must match request {i}")

        # group request indices by (length bucket, dtype) — plus the value
        # signature for sort_kv, so unrelated payload shapes never collide
        groups: Dict[tuple, List[int]] = {}
        for i, r in enumerate(reqs):
            gk = (size_bucket(len(r), min_bucket=self.min_bucket), r.dtype.name)
            if vals is not None:
                gk += (vals[i].shape[1:], vals[i].dtype.name)
            groups.setdefault(gk, []).append(i)

        out: List[Any] = [None] * len(reqs)
        for gk, idxs in sorted(groups.items(), key=lambda kv: repr(kv[0])):
            bucket, dtype_name = gk[0], gk[1]
            dtype = np.dtype(dtype_name)
            bb = size_bucket(len(idxs), min_bucket=1)  # pow2 batch bucket
            sent = _np_sentinel(dtype, largest=ascending)
            batch = np.full((bb, bucket), sent, dtype)
            for row, i in enumerate(idxs):
                batch[row, : len(reqs[i])] = reqs[i]

            plan = self.planner.plan_for(bucket, dtype)
            if plan.strategy != "shared":  # front door is single-host
                plan = SortPlan("shared")
            # the executable identity is exactly the plan fields this kind
            # consumes (block_n changes the traced program for pallas plans)
            impl, block_n, n_threads = self._plan_fields(kind, plan)
            key = (kind, bucket, bb, dtype_name, ascending,
                   impl, n_threads, block_n)
            args = [jax.ShapeDtypeStruct((bb, bucket), jnp.dtype(dtype))]

            if kind == "sort_kv":
                vshape, vdtype = gk[2], np.dtype(gk[3])
                vbatch = np.zeros((bb, bucket) + vshape, vdtype)
                for row, i in enumerate(idxs):
                    vbatch[row, : len(vals[i])] = vals[i]
                key = key + (vshape, vdtype.name)
                args.append(jax.ShapeDtypeStruct((bb, bucket) + vshape, jnp.dtype(vdtype)))

            before = self.cache.misses
            exe = self.cache.get_or_build(key, self._builder(kind, plan, ascending), args)
            self.stats.compiles += self.cache.misses - before
            self.stats.cache_hits += int(self.cache.misses == before)
            self.stats.batches += 1
            self.stats.padded_keys += bb * bucket - sum(len(reqs[i]) for i in idxs)

            if kind == "sort_kv":
                ks, vres = exe(batch, vbatch)
                ks, vres = np.asarray(ks), np.asarray(vres)
                for row, i in enumerate(idxs):
                    n = len(reqs[i])
                    out[i] = (ks[row, :n], vres[row, :n])
            else:
                res = np.asarray(exe(batch))
                for row, i in enumerate(idxs):
                    # sentinel padding sorts last either direction, so the
                    # leading n entries (indices < n for argsort) are the answer
                    out[i] = res[row, : len(reqs[i])]

        self.stats.requests += len(reqs)
        self.stats.keys_in += sum(len(r) for r in reqs)
        self.stats.elapsed_s += time.perf_counter() - t0
        return out
