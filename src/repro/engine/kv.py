"""Key–value sorting — the cluster model finally sorts records, not just keys.

``sort_kv`` / ``argsort`` / ``sort_pairs`` ride the existing
``partition_exchange`` values path (model D's one-step MSD-radix all_to_all),
so an arbitrary pytree of per-record payloads ships alongside the keys in the
same collective — including ``compress=True`` int8 wire mode.  Stability falls
out of the slab layout: within a bucket, receive order is (sender shard, slot
in sender's slab) which *is* global arrival order, so a stable local argsort
of the received slab reproduces ``np.argsort(kind='stable')`` exactly.

Single-device calls (``mesh=None``) use a stable XLA argsort + gather; the
distributed path requires 1-D keys with length divisible by the axis size.
"""
from __future__ import annotations

from functools import lru_cache, partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.radix import make_partitioner
from repro.exchange import (
    partition_exchange,
    partition_of,
    run_with_capacity_retries,
    slab_geometry,
    slab_valid,
)

__all__ = ["sort_kv", "sort_pairs", "argsort", "topk", "cluster_sort_kv"]


# --------------------------------------------------------------- local path ---
def _rev_key(keys: jax.Array) -> jax.Array:
    """Order-reversing self-inverse bijection: negation for floats, bitwise
    NOT for ints (~x = -x-1 is strictly decreasing; even INT_MIN is safe)."""
    if jnp.issubdtype(keys.dtype, jnp.integer):
        return ~keys
    return -keys


def _order_keys(
    keys: jax.Array,
    *,
    ascending: bool,
    impl: str = "xla",
    block_n: Optional[int] = None,
) -> jax.Array:
    """Stable argsort along the last axis, either direction.

    Descending stability (ties keep original order) sorts the reversed-order
    key transform ascending. ``impl='pallas'`` routes through the kernel's
    stable (key, rank) network — identical permutation, VMEM-tiled execution
    (but unspecified output for NaN keys, which only 'xla' totally orders).
    """
    k = keys if ascending else _rev_key(keys)
    if impl == "pallas":
        from repro.kernels.bitonic_sort.ops import (
            DEFAULT_BLOCK_N,
            pallas_argsort,
            vmap_last_axis,
        )

        return vmap_last_axis(
            partial(pallas_argsort, block_n=block_n or DEFAULT_BLOCK_N), k
        )
    if impl != "xla":
        raise ValueError(f"argsort impl must be 'xla' or 'pallas', got {impl!r}")
    return jnp.argsort(k, axis=-1, stable=True)


def _gather_last(v: jax.Array, order: jax.Array) -> jax.Array:
    """Index ``v`` (shaped like keys + optional trailing dims) by ``order``."""
    extra = v.ndim - order.ndim
    idx = order.reshape(order.shape + (1,) * extra)
    return jnp.take_along_axis(v, idx, axis=order.ndim - 1)


# ------------------------------------------------------------- cluster path ---
def cluster_kv_local(
    local_keys: jax.Array,
    local_values: Any,
    axis_name: str,
    *,
    capacity: int,
    partitioner,
    n_buckets: int,
    compress: bool = False,
):
    """shard_map body: exchange (key, value) records, stable-sort the slab.

    Returns (sorted_keys (B/P*C,), sorted_values pytree, my_count, peak,
    overflow).  Entries [0, my_count) are this shard's contiguous range of
    the global stable sort; the tail is sentinel/zero padding; ``peak`` is
    the mesh-wide max per-(sender, bucket) count (capacity-learning signal).
    """
    P_ = jax.lax.axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    bucket = partitioner(local_keys).astype(jnp.int32)
    ex = partition_exchange(
        local_keys,
        local_values,
        bucket,
        axis_name,
        capacity=capacity,
        n_buckets=n_buckets,
        compress=compress,
    )
    flat_k = ex.recv_keys.reshape(-1)
    # slab flat index = (sender, local bucket, slot): within one bucket this is
    # global arrival order, so a stable sort here == the global stable sort.
    order = jnp.argsort(flat_k, stable=True)
    sorted_k = flat_k[order]
    sorted_v = jax.tree.map(
        lambda v: v.reshape((flat_k.shape[0],) + v.shape[2:])[order], ex.recv_values
    )
    global_counts = jax.lax.psum(ex.counts, axis_name)  # (n_buckets,)
    owner = (jnp.arange(n_buckets, dtype=jnp.int32) * P_) // n_buckets
    my_count = jnp.sum(jnp.where(owner == idx, global_counts, 0)).astype(jnp.int32)
    peak = jax.lax.pmax(jnp.max(ex.counts), axis_name)
    return sorted_k, sorted_v, my_count[None], peak, ex.overflow


@lru_cache(maxsize=256)
def _compiled_cluster_kv(
    mesh, axis, mode, capacity, part_buckets, n_buckets, digits, lo, hi, compress
):
    """One jitted shard_map per static config (jit still specializes per
    values-pytree structure internally) — repeat traffic never re-traces."""
    # stable=True: the kv contract is a *stable* sort, so sample mode must use
    # arrival-order tie ids (bucket boundaries inside tie runs keep arrival
    # order across buckets; the slab layout keeps it within buckets)
    part = make_partitioner(
        mode, n_buckets=part_buckets, digits=digits, lo=lo, hi=hi, axis_name=axis,
        stable=True,
    )
    body = partial(
        cluster_kv_local,
        axis_name=axis,
        capacity=capacity,
        partitioner=part,
        n_buckets=n_buckets,
        compress=compress,
    )
    return jax.jit(
        jax.shard_map(
            body,
            mesh=mesh,
            in_specs=(P(axis), P(axis)),
            out_specs=(P(axis), P(axis), P(axis), P(), P()),
        )
    )


def cluster_sort_kv(
    keys: jax.Array,
    values: Any,
    mesh,
    axis: str,
    *,
    mode: str = "splitters",
    capacity_factor: float = 2.0,
    digits: int = 3,
    lo=0,
    hi=1,
    compress: bool = False,
    max_retries: int = 4,
    telemetry=None,
):
    """Distributed stable key–value sort (model D with a values payload).

    Returns (slab_keys (P*C_total,), slab_values pytree, valid mask); shard
    p's range of the globally sorted records sits in its slab prefix.  Retries
    with doubled capacity on overflow, like ``cluster_sort`` — and like it,
    reports per-call exchange telemetry (peak bucket count, overflow/retry/
    recompile events) through the optional ``telemetry`` callback that
    ``repro.engine.adapt`` turns into learned capacity factors.

    >>> import jax, jax.numpy as jnp
    >>> mesh = jax.make_mesh((jax.device_count(),), ("x",))
    >>> keys = jnp.arange(16)[::-1]
    >>> slab, vals, valid = cluster_sort_kv(keys, {"i": jnp.arange(16)}, mesh, "x")
    >>> [int(v) for v in slab[valid][:4]]
    [0, 1, 2, 3]
    >>> [int(v) for v in vals["i"][valid][:4]]   # payload rides along
    [15, 14, 13, 12]
    """
    P_ = mesh.shape[axis]
    n = keys.shape[-1]
    if n % P_:
        raise ValueError(f"n={n} must divide axis size {P_}")
    m = n // P_
    part_buckets, n_buckets, cap = slab_geometry(mode, m, P_, capacity_factor)

    (slab_k, slab_v), counts = run_with_capacity_retries(
        lambda c: _compiled_cluster_kv(
            mesh, axis, mode, c, part_buckets, n_buckets, digits, lo, hi, compress
        ),
        lambda fn: fn(keys, values),
        m=m,
        part_buckets=part_buckets,
        cap=cap,
        max_retries=max_retries,
        telemetry=telemetry,
        lru=_compiled_cluster_kv,
        label="cluster_sort_kv",
        partition=partition_of(mode),
    )
    return slab_k, slab_v, slab_valid(slab_k.shape[0], counts, P_)


# ---------------------------------------------------------------- front API ---
def sort_kv(
    keys: jax.Array,
    values: Any,
    *,
    mesh=None,
    axis: Optional[str] = None,
    ascending: bool = True,
    compress: bool = False,
    impl: str = "xla",
    block_n: Optional[int] = None,
    **cluster_kw,
):
    """Stable sort of ``keys`` carrying an arbitrary ``values`` pytree along.

    Single device: any leading batch dims, sorts the last axis; ``impl=``
    picks the local argsort engine ('xla' or 'pallas', ``block_n`` = kernel
    tile width; only 'xla' totally orders NaN keys).  With ``mesh=``/
    ``axis=``: 1-D keys, model-D exchange of full records, returns dense
    (n,)-shaped results (the slab is compacted eagerly).  The mesh path
    closes the capacity-learning loop by default — it runs at the default
    planner's learned ``capacity_factor`` for this (size, dtype, mesh) cell
    and reports exchange telemetry back (pass ``capacity_factor=`` or
    ``telemetry=`` to opt out; see repro.engine.adapt).

    >>> import jax.numpy as jnp
    >>> k, v = sort_kv(jnp.array([3, 1, 2]), {"p": jnp.array([0, 1, 2])})
    >>> [int(i) for i in v["p"]]
    [1, 2, 0]
    """
    if mesh is None:
        order = _order_keys(keys, ascending=ascending, impl=impl, block_n=block_n)
        return _gather_last(keys, order), jax.tree.map(
            lambda v: _gather_last(v, order), values
        )
    if axis is None:
        raise ValueError("sort_kv with mesh= requires axis=")
    if not ascending:
        # sort the order-reversed keys ascending so ties keep arrival order
        # (a flip of the ascending result would reverse them); decimal/range
        # bucketing assumes the untransformed key space, the data-adaptive
        # modes (splitters/sample/auto-ranged radix) don't care.
        if cluster_kw.get("mode", "splitters") not in ("splitters", "sample", "radix"):
            raise ValueError(
                "descending distributed sort_kv needs a data-adaptive mode "
                "('splitters', 'sample', or 'radix')"
            )
        k, v = sort_kv(
            _rev_key(keys), values, mesh=mesh, axis=axis, ascending=True,
            compress=compress, **cluster_kw,
        )
        return _rev_key(k), v
    if "capacity_factor" not in cluster_kw and "telemetry" not in cluster_kw:
        # close the capacity-learning loop through the default planner; an
        # explicit capacity_factor= or telemetry= opts out of the whole loop
        from .planner import default_planner

        cluster_kw.update(
            default_planner().cluster_kwargs(
                keys.shape[-1], keys.dtype, mesh, mode=cluster_kw.get("mode")
            )
        )
    slab_k, slab_v, valid = cluster_sort_kv(
        keys, values, mesh, axis, compress=compress, **cluster_kw
    )
    return slab_k[valid], jax.tree.map(lambda a: a[valid], slab_v)


def sort_pairs(keys: jax.Array, values: jax.Array, **kwargs):
    """(keys, values) -> (sorted_keys, aligned_values) for a single payload
    array — the record-sort convenience wrapper over ``sort_kv``.

    >>> import jax.numpy as jnp
    >>> k, v = sort_pairs(jnp.array([2, 1]), jnp.array([10, 20]))
    >>> [int(x) for x in v]
    [20, 10]
    """
    k, v = sort_kv(keys, {"v": values}, **kwargs)
    return k, v["v"]


def argsort(
    keys: jax.Array,
    *,
    mesh=None,
    axis: Optional[str] = None,
    ascending: bool = True,
    impl: str = "xla",
    block_n: Optional[int] = None,
    **cluster_kw,
):
    """Stable argsort (indices into the original array), matching
    ``np.argsort(kind='stable')``. Distributed path carries the global index
    as the exchange payload; single-device ``impl='pallas'`` runs the kernel's
    stable (key, rank) network.

    >>> import jax.numpy as jnp
    >>> [int(i) for i in argsort(jnp.array([30, 10, 20]))]
    [1, 2, 0]
    """
    if mesh is None:
        return _order_keys(keys, ascending=ascending, impl=impl, block_n=block_n)
    iota = jnp.arange(keys.shape[-1], dtype=jnp.int32)
    _, idx = sort_pairs(
        keys, iota, mesh=mesh, axis=axis, ascending=ascending, **cluster_kw
    )
    return idx


def topk(
    x: jax.Array,
    k: int,
    *,
    largest: bool = True,
    impl: str = "xla",
    block_n: Optional[int] = None,
):
    """Top-k (values, indices) along the last axis via the engine argsort.

    Matches ``jax.lax.top_k`` tie behaviour (lowest index wins) because the
    descending argsort is stable — with ``impl='pallas'`` included, since the
    kernel's (key, rank) comparator is stable by construction.

    >>> import jax.numpy as jnp
    >>> vals, idx = topk(jnp.array([1.0, 9.0, 4.0]), 2)
    >>> [float(v) for v in vals], [int(i) for i in idx]
    ([9.0, 4.0], [1, 2])
    """
    order = _order_keys(x, ascending=not largest, impl=impl, block_n=block_n)
    top_idx = order[..., :k]
    return jnp.take_along_axis(x, top_idx, axis=-1), top_idx
