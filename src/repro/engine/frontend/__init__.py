"""repro.engine.frontend — the SLO-aware multi-tenant serving front end.

warmup    : AOT-compile every executable cell the plan cache names before
            traffic arrives, so first-request latency == steady-state
            latency (``warmup`` / ``WarmupReport`` / ``batch_bucket_ladder``)
scheduler : ``SortFrontend`` — per-tenant weighted admission over a bounded
            backlog, strict priority classes with EDF dispatch inside each,
            explicit reject-with-reason load shedding (``Tenant`` /
            ``Ticket`` / ``ShedError`` / ``BatchInfo``)
loadgen   : reproducible open-loop load (seeded Poisson arrivals, Zipfian
            size mix, tenant skew) with deterministic ``ManualClock``
            simulation and wall-clock replay, reporting p50/p95/p99 latency
            and goodput under overload (``make_trace`` / ``run_load`` /
            ``replay_wallclock`` / ``LoadReport``)

The pieces compose into the serving story docs/serving.md tells: warm the
ladder, admit by contract, dispatch by deadline, shed with a reason, and
prove the whole thing with the load harness — which doubles as the
regression gate behind ``benchmarks/engine_bench.py --snapshot/--compare``.
"""
from .loadgen import (
    Arrival,
    LoadReport,
    linear_service_time,
    make_trace,
    payload_for,
    replay_wallclock,
    run_load,
    zipf_shares,
)
from .scheduler import BatchInfo, ShedError, SortFrontend, Tenant, Ticket
from .warmup import WarmupReport, batch_bucket_ladder, warmup

__all__ = [
    "Arrival",
    "BatchInfo",
    "LoadReport",
    "ShedError",
    "SortFrontend",
    "Tenant",
    "Ticket",
    "WarmupReport",
    "batch_bucket_ladder",
    "linear_service_time",
    "make_trace",
    "payload_for",
    "replay_wallclock",
    "run_load",
    "warmup",
    "zipf_shares",
]
