"""SLO-aware multi-tenant scheduler over the batched sort service.

``AsyncSortService`` (repro.engine.queue) batches well but treats every
caller identically: one FIFO, one flush window, block-or-reject
backpressure.  A serving front end shared by multiple tenants needs three
things that FIFO can't give:

* **priority classes** — an interactive tenant's requests must dispatch
  before a batch tenant's, full stop;
* **deadline-based dispatch** — within a priority class, the request
  closest to missing its SLO runs first (EDF, the classic optimal
  single-server policy for feasible deadline sets);
* **an explicit load-shed policy** — when the bounded backlog saturates,
  *somebody* must be told "no", immediately, with a reason, and the refusal
  must be attributed to the right tenant (``QueueStats.shed``) instead of
  silently inflating everyone's tail latency.

``SortFrontend`` implements exactly that on top of ``SortService``'s
group/pad/execute core: requests are admitted against per-tenant weighted
backlog bounds (each tenant's guaranteed slice of ``maxsize`` is
proportional to its weight), dispatch picks the most urgent pending request
(priority class, then earliest deadline, then arrival order) and coalesces
every compatible pending request — across tenants — into one executable
batch behind it.  Expired requests are shed at dispatch rather than
executed (configurable: serving paths that must answer every request pass
``shed_expired=False`` and count the SLO miss instead).

Like the rest of the engine, all timing flows through an injectable clock:
tests and the open-loop load harness (``repro.engine.frontend.loadgen``)
drive dispatch deterministically on a ``ManualClock`` via ``pump()``;
production wraps the same core in a background dispatcher thread
(``start()`` / ``close()``).
"""
from __future__ import annotations

import math
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..queue import QueueStats
from ..service import SortService
from .warmup import WarmupReport, warmup

__all__ = ["Tenant", "ShedError", "Ticket", "BatchInfo", "SortFrontend"]

_INF = float("inf")


@dataclass(frozen=True)
class Tenant:
    """One tenant's serving contract.

    ``priority`` is a strict class (lower dispatches first); ``weight``
    apportions the bounded backlog — tenant i's guaranteed admission slice
    is ``ceil(weight_i / total_weight * maxsize)`` requests; ``slo_ms`` is
    the default deadline budget stamped on its requests at submit.

    >>> Tenant("interactive", weight=3.0, priority=0, slo_ms=50.0).name
    'interactive'
    """

    name: str
    weight: float = 1.0
    priority: int = 0
    slo_ms: Optional[float] = None
    max_backlog: Optional[int] = None  # explicit override of the weighted slice

    def __post_init__(self):
        if self.weight <= 0:
            raise ValueError("tenant weight must be positive")
        if self.slo_ms is not None and self.slo_ms <= 0:
            raise ValueError("slo_ms must be positive (or None for no SLO)")


class ShedError(RuntimeError):
    """A request the frontend refused (admission) or expired (dispatch).

    ``reason`` is machine-readable: ``'tenant_backlog'`` (the tenant's
    weighted backlog slice is full), ``'global_backlog'`` (the whole bounded
    backlog is full), or ``'deadline'`` (the request expired in queue before
    dispatch).  The same (tenant, reason) pair lands in
    ``QueueStats.shed`` so overload is attributable after the fact.

    >>> ShedError("batch", "tenant_backlog").reason
    'tenant_backlog'
    """

    def __init__(self, tenant: str, reason: str):
        super().__init__(f"request shed for tenant {tenant!r}: {reason}")
        self.tenant = tenant
        self.reason = reason


class Ticket:
    """One admitted request: a Future plus its SLO bookkeeping.

    ``result()`` / ``done()`` delegate to the underlying Future; ``t_submit``
    / ``t_done`` are stamps on the frontend's injected clock, so
    ``latency_s`` and ``slo_met`` are deterministic under ``ManualClock``.

    >>> import numpy as np
    >>> fe = SortFrontend(tenants=[Tenant("t")], start=False)
    >>> t = fe.submit("t", np.array([3, 1, 2], np.int32))
    >>> fe.poll()                      # one pumped batch
    1
    >>> [int(v) for v in t.result()], t.slo_met   # no SLO -> trivially met
    ([1, 2, 3], True)
    """

    __slots__ = ("tenant", "t_submit", "deadline", "t_done", "future")

    def __init__(self, tenant: str, t_submit: float, deadline: float):
        self.tenant = tenant
        self.t_submit = t_submit
        self.deadline = deadline  # absolute clock time; inf = no SLO
        self.t_done: Optional[float] = None
        self.future: Future = Future()

    def result(self, timeout: Optional[float] = None):
        return self.future.result(timeout=timeout)

    def done(self) -> bool:
        return self.future.done()

    @property
    def latency_s(self) -> Optional[float]:
        """Submit-to-resolution time on the frontend clock (None while
        pending or if the request was shed)."""
        return None if self.t_done is None else self.t_done - self.t_submit

    @property
    def slo_met(self) -> bool:
        """Completed (not shed) at or before its deadline."""
        return (
            self.t_done is not None
            and not self.future.exception()
            and self.t_done <= self.deadline
        )


@dataclass(frozen=True)
class BatchInfo:
    """What one ``pump()`` dispatched: the load harness's cost-model input.

    >>> BatchInfo(n_requests=4, bucket=1024, kind="sort",
    ...           tenants=("a", "b")).n_requests
    4
    """

    n_requests: int
    bucket: int
    kind: str
    tenants: Tuple[str, ...]


class _Pending:
    __slots__ = ("tenant", "priority", "deadline", "seq", "sig", "req", "val",
                 "ticket")

    def __init__(self, tenant, priority, deadline, seq, sig, req, val, ticket):
        self.tenant = tenant
        self.priority = priority
        self.deadline = deadline
        self.seq = seq
        self.sig = sig  # (kind, ascending) + service group key
        self.req = req
        self.val = val
        self.ticket = ticket

    @property
    def urgency(self):
        return (self.priority, self.deadline, self.seq)


class SortFrontend:
    """Multi-tenant, SLO-aware front door over one ``SortService``.

    Parameters
    ----------
    service:      the ``SortService`` to execute on (shares its compiled
                  cache — and hence its AOT warmup — with every other path).
    tenants:      the serving contracts; submits for unknown tenants raise.
    max_batch:    coalescing cap per dispatched batch.
    maxsize:      bound on admitted-but-undispatched requests across all
                  tenants; each tenant's guaranteed slice is its weighted
                  share (see ``Tenant``).
    shed_expired: shed requests whose deadline passed before dispatch
                  (``ShedError('deadline')`` on the ticket's future) instead
                  of executing them late.  Serving paths that must answer
                  every request pass False and count the SLO miss.
    clock:        monotonic time source for every admission/dispatch/SLO
                  decision (``ManualClock`` in tests and simulations).
    start:        launch the background dispatcher thread.  The default is
                  False: pump-driven operation (``pump()`` / ``poll()``) is
                  the deterministic mode the load harness and tests use.

    >>> import numpy as np
    >>> fe = SortFrontend(tenants=[Tenant("web", priority=0),
    ...                            Tenant("batch", priority=1)])
    >>> t1 = fe.submit("batch", np.array([2, 1], np.int32))
    >>> t2 = fe.submit("web", np.array([4, 3], np.int32))
    >>> fe.pump().tenants   # web's priority class leads; batch coalesces in
    ('web', 'batch')
    >>> [int(v) for v in t2.result()]
    [3, 4]
    """

    def __init__(
        self,
        service: Optional[SortService] = None,
        *,
        tenants: Sequence[Tenant],
        max_batch: int = 16,
        maxsize: int = 256,
        shed_expired: bool = True,
        clock=time.monotonic,
        start: bool = False,
        poll_interval_s: float = 0.002,
    ):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if maxsize < 1:
            raise ValueError("maxsize must be >= 1")
        self.service = service if service is not None else SortService()
        if not isinstance(self.service.stats, QueueStats):
            # widen in place, same trick as AsyncSortService: one shared ledger
            self.service.stats = QueueStats(**vars(self.service.stats))
        self.tenants: Dict[str, Tenant] = {}
        for t in tenants:
            if t.name in self.tenants:
                raise ValueError(f"duplicate tenant {t.name!r}")
            self.tenants[t.name] = t
        if not self.tenants:
            raise ValueError("need at least one tenant")
        total_w = sum(t.weight for t in self.tenants.values())
        self._bounds = {
            t.name: (
                t.max_backlog
                if t.max_backlog is not None
                else max(1, math.ceil(t.weight / total_w * maxsize))
            )
            for t in self.tenants.values()
        }
        self.max_batch = int(max_batch)
        self.maxsize = int(maxsize)
        self.shed_expired = shed_expired
        self._clock = clock
        self._poll_s = poll_interval_s
        self._pending: List[_Pending] = []
        self._per_tenant: Dict[str, int] = {name: 0 for name in self.tenants}
        self._seq = 0
        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        self._closed = False
        self._started = False
        self._thread = threading.Thread(
            target=self._dispatch_loop, name="SortFrontend", daemon=True
        )
        if start:
            self.start()

    # ------------------------------------------------------------ lifecycle ---
    @property
    def stats(self) -> QueueStats:
        """The shared service ledger (batches, sheds, per-tenant tallies)."""
        return self.service.stats

    def backlog(self, tenant: Optional[str] = None) -> int:
        """Admitted-but-undispatched requests (for one tenant, or all)."""
        with self._lock:
            if tenant is not None:
                return self._per_tenant[tenant]
            return len(self._pending)

    def tenant_backlog_bound(self, tenant: str) -> int:
        """The tenant's guaranteed admission slice of ``maxsize``."""
        return self._bounds[tenant]

    def warmup(self, **kwargs) -> WarmupReport:
        """AOT-warm this frontend's service for its own batch ladder
        (``repro.engine.frontend.warmup`` with ``max_batch`` defaulted to the
        scheduler's — every batch shape a pump can flush pre-compiles)."""
        kwargs.setdefault("max_batch", self.max_batch)
        return warmup(self.service, **kwargs)

    def start(self) -> "SortFrontend":
        """Launch the background dispatcher thread (idempotent)."""
        if not self._started:
            self._started = True
            self._thread.start()
        return self

    def close(self) -> None:
        """Stop admission, drain the backlog, stop the dispatcher thread."""
        with self._lock:
            if self._closed:
                return
            self._closed = True
            self._work.notify_all()
        if self._started:
            self._thread.join(timeout=30)
        self.run_until_idle()  # pump-mode users: drain synchronously

    def __enter__(self) -> "SortFrontend":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -------------------------------------------------------------- submit ---
    def submit(
        self,
        tenant: str,
        keys: np.ndarray,
        *,
        kind: str = "sort",
        values: Optional[np.ndarray] = None,
        ascending: bool = True,
        deadline: Optional[float] = None,
    ) -> Ticket:
        """Admit one request for ``tenant``; returns a ``Ticket``.

        ``deadline`` is an absolute time on the frontend clock; omitted, it
        defaults to ``now + tenant.slo_ms`` (or no deadline for tenants
        without an SLO).  Validation errors raise synchronously; admission
        refusals raise ``ShedError`` with the reason and are attributed to
        the tenant in ``QueueStats.shed``.
        """
        cfg = self.tenants.get(tenant)
        if cfg is None:
            raise KeyError(f"unknown tenant {tenant!r}")
        reqs, vals = self.service._validate(
            kind, [keys], [values] if values is not None else None
        )
        req = np.array(reqs[0], copy=True)  # snapshot the caller's buffers
        val = np.array(vals[0], copy=True) if vals is not None else None
        sig = (kind, bool(ascending)) + self.service._group_key(req, val)
        now = self._clock()
        if deadline is None:
            deadline = now + cfg.slo_ms / 1e3 if cfg.slo_ms is not None else _INF
        with self._lock:
            if self._closed:
                raise RuntimeError("SortFrontend is closed")
            if len(self._pending) >= self.maxsize:
                self.stats.observe_shed(tenant, "global_backlog")
                raise ShedError(tenant, "global_backlog")
            if self._per_tenant[tenant] >= self._bounds[tenant]:
                self.stats.observe_shed(tenant, "tenant_backlog")
                raise ShedError(tenant, "tenant_backlog")
            ticket = Ticket(tenant, now, deadline)
            self._pending.append(
                _Pending(tenant, cfg.priority, deadline, self._seq, sig,
                         req, val, ticket)
            )
            self._seq += 1
            self._per_tenant[tenant] += 1
            self.stats.enqueued += 1
            self._work.notify_all()
        return ticket

    # ------------------------------------------------------------ dispatch ---
    def _shed_expired_locked(self, now: float) -> None:
        keep: List[_Pending] = []
        for p in self._pending:
            if p.deadline < now:
                self._per_tenant[p.tenant] -= 1
                self.stats.observe_shed(p.tenant, "deadline")
                p.ticket.t_done = now
                if p.ticket.future.set_running_or_notify_cancel():
                    p.ticket.future.set_exception(
                        ShedError(p.tenant, "deadline")
                    )
            else:
                keep.append(p)
        self._pending = keep

    def pump(self) -> Optional[BatchInfo]:
        """Dispatch the single most urgent batch; None if nothing is pending.

        Selection: shed expired requests (when ``shed_expired``), pick the
        pending request with the best ``(priority, deadline, arrival)``
        urgency, then coalesce every compatible pending request — same
        (kind, direction, length bucket, dtype) signature, any tenant — in
        urgency order up to ``max_batch``, and execute the batch through the
        service's shared pad/plan/execute core.
        """
        now = self._clock()
        with self._lock:
            if self.shed_expired:
                self._shed_expired_locked(now)
            if not self._pending:
                return None
            head = min(self._pending, key=lambda p: p.urgency)
            mates = sorted(
                (p for p in self._pending if p.sig == head.sig),
                key=lambda p: p.urgency,
            )[: self.max_batch]
            taken = set(id(p) for p in mates)
            self._pending = [p for p in self._pending if id(p) not in taken]
            for p in mates:
                self._per_tenant[p.tenant] -= 1

        kind, ascending = head.sig[0], head.sig[1]
        gk = head.sig[2:]
        reqs = [p.req for p in mates]
        vals = [p.val for p in mates] if kind == "sort_kv" else None
        live = [p for p in mates
                if p.ticket.future.set_running_or_notify_cancel()]
        if not live:
            return BatchInfo(0, gk[0], kind, ())
        try:
            results = self.service._run_group(
                kind, gk, reqs, vals, ascending=ascending
            )
        except Exception as e:
            t_done = self._clock()
            for p in live:
                p.ticket.t_done = t_done
                p.ticket.future.set_exception(e)
            return BatchInfo(len(live), gk[0], kind, tuple(p.tenant for p in live))
        t_done = self._clock()
        with self.service._lock:
            self.stats.observe_batch(
                n_requests=len(live),
                capacity=self.max_batch,
                latencies=[t_done - p.ticket.t_submit for p in live],
            )
            for p in live:
                self.stats.tenant_served[p.tenant] = (
                    self.stats.tenant_served.get(p.tenant, 0) + 1
                )
        by_id = {id(p): r for p, r in zip(mates, results)}
        for p in live:
            p.ticket.t_done = t_done
            p.ticket.future.set_result(by_id[id(p)])
        return BatchInfo(len(live), gk[0], kind, tuple(p.tenant for p in live))

    def poll(self) -> int:
        """Pump until nothing is dispatchable; returns batches executed."""
        n = 0
        while self.pump() is not None:
            n += 1
        return n

    run_until_idle = poll

    def _dispatch_loop(self) -> None:
        while True:
            info = self.pump()
            if info is not None:
                continue
            with self._lock:
                if self._closed and not self._pending:
                    return
                if not self._pending:
                    # poll-bounded wait: deadline sheds need periodic wakeups
                    self._work.wait(timeout=self._poll_s)
