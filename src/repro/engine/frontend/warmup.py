"""AOT warmup: compile the whole executable ladder before traffic arrives.

A lazily-compiling serving process pays jax tracing + XLA compilation on the
first request of every (kind, length bucket, batch bucket, dtype, direction)
cell — tens to hundreds of milliseconds of first-request latency that steady
state never sees again.  A production front end compiles its whole bucket
ladder ahead of time instead (the ``warmup()``/``interesting_buckets``
pattern MLPerf-style inference servers use): ``warmup(service, plan_table)``
enumerates every (size bucket, dtype) cell the plan cache names
(``Planner.warmup_cells`` — tuned plans *and* learned-capacity cells, i.e.
everywhere real traffic has ever landed), crosses it with the request kinds
and the pow2 batch-bucket ladder the service pads into, and compiles each
cell through the exact executable-identity path serving uses
(``SortService.warm_cell`` -> ``_signature``).  After warmup, a request for
any warmed cell is a pure cache hit: **zero** fresh jax lowerings, proven
with jax's compilation counters in tests/test_frontend.py.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Iterable, Optional, Sequence, Tuple

import numpy as np

from ..cache import size_bucket
from ..planner import Planner
from ..service import SortService

__all__ = ["WarmupReport", "batch_bucket_ladder", "warmup"]


def batch_bucket_ladder(max_batch: int) -> Tuple[int, ...]:
    """The pow2 batch buckets serving can pad a coalesced batch into.

    A scheduler flushing up to ``max_batch`` requests produces batches of
    every size in ``1..max_batch``; the service pads each to its pow2 batch
    bucket, so these — and only these — batch shapes can ever compile.

    >>> batch_bucket_ladder(8)
    (1, 2, 4, 8)
    >>> batch_bucket_ladder(6)
    (1, 2, 4, 8)
    """
    ladder = []
    bb = 1
    while bb < max_batch:
        ladder.append(bb)
        bb *= 2
    ladder.append(bb)
    return tuple(ladder)


@dataclass
class WarmupReport:
    """What one ``warmup`` call compiled (and skipped as already warm).

    ``cells`` lists every executable cell visited as
    ``(kind, bucket, dtype, batch_bucket, ascending)``; ``compiled`` counts
    the fresh executables this call built, ``cached`` the cells that were
    already warm (a second warmup is a fast no-op), ``elapsed_s`` the wall
    time the compiles took — the latency the *first requests* would have
    paid without warmup.

    >>> WarmupReport(cells=[], compiled=0, cached=0, elapsed_s=0.0).compiled
    0
    """

    cells: list = field(default_factory=list)
    compiled: int = 0
    cached: int = 0
    elapsed_s: float = 0.0

    def summary(self) -> str:
        """One printable line for serve drivers' ``--warmup`` output."""
        return (
            f"warmup: {len(self.cells)} cells, {self.compiled} compiled, "
            f"{self.cached} already warm, {self.elapsed_s * 1e3:.0f} ms"
        )


def warmup(
    service: Optional[SortService] = None,
    plan_table: Optional[Planner] = None,
    *,
    cells: Optional[Iterable[Tuple[int, object]]] = None,
    kinds: Sequence[str] = ("sort", "argsort"),
    max_batch: int = 16,
    ascending: Sequence[bool] = (True,),
    values_spec: Optional[Tuple[tuple, object]] = None,
    mesh=None,
) -> WarmupReport:
    """AOT-compile every executable cell the plan table names.

    Parameters
    ----------
    service:    the ``SortService`` whose compiled cache to warm (a fresh one
                by default — but warming a fresh private service is rarely
                what you want: pass the service your scheduler serves on).
    plan_table: the ``Planner`` whose plan-cache keys enumerate the (bucket,
                dtype) cells; defaults to ``service.planner``.  Cells come
                from ``Planner.warmup_cells(mesh)`` — every key the tuned
                ``plans`` table or the ``learned`` capacity section holds for
                this hardware fingerprint.
    cells:      explicit extra ``(size, dtype)`` cells to warm in addition to
                (or, with an empty plan table, instead of) the enumerated
                ones — sizes are bucketed with ``size_bucket`` first, so any
                expected request length works.
    kinds:      request kinds to compile per cell.  ``sort_kv`` requires
                ``values_spec=(trailing value shape, value dtype)``.
    max_batch:  top of the pow2 batch-bucket ladder — use the scheduler's
                ``max_batch`` so every flushable batch shape is covered.
    ascending:  sort directions to compile (descending argsort is the
                serving top-k shape: ``ascending=(False,)``).
    mesh:       hardware fingerprint to enumerate plan cells for (None =
                this process's local fingerprint, the serving case).

    >>> svc = SortService(planner=Planner())   # hermetic plan table
    >>> rep = warmup(svc, cells=[(1000, "int32")], kinds=("sort",),
    ...              max_batch=2)
    >>> (rep.compiled, rep.cached)            # (1024,)x{1,2}: two cells
    (2, 0)
    >>> warmup(svc, cells=[(1000, "int32")], kinds=("sort",),
    ...        max_batch=2).compiled          # idempotent: already warm
    0
    """
    service = service if service is not None else SortService()
    planner = plan_table if plan_table is not None else service.planner
    targets = list(planner.warmup_cells(mesh))
    if cells is not None:
        for n, dtype in cells:
            targets.append(
                (size_bucket(int(n), min_bucket=service.min_bucket),
                 np.dtype(dtype).name)
            )
    # dedupe while keeping deterministic order
    targets = sorted(set(targets))

    report = WarmupReport()
    t0 = time.perf_counter()
    for bucket, dtype_name in targets:
        for kind in kinds:
            for asc in ascending:
                for bb in batch_bucket_ladder(max_batch):
                    fresh = service.warm_cell(
                        kind,
                        bucket,
                        dtype_name,
                        batch_bucket=bb,
                        ascending=asc,
                        values_spec=values_spec if kind == "sort_kv" else None,
                    )
                    report.cells.append((kind, bucket, dtype_name, bb, asc))
                    report.compiled += int(fresh)
                    report.cached += int(not fresh)
    report.elapsed_s = time.perf_counter() - t0
    return report
