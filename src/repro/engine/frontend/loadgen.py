"""Open-loop load harness: seeded Poisson/Zipf traffic, deterministic replay.

Measuring a serving frontend honestly requires *open-loop* load — arrivals
fire on their own schedule whether or not the server keeps up, so overload
actually builds a backlog instead of politely self-throttling (the
closed-loop trap).  This module generates reproducible open-loop traffic and
replays it against a ``SortFrontend`` in two modes:

* **Simulation** (``run_load``): the frontend runs on a ``ManualClock`` and
  a ``service_time`` cost model charges simulated seconds per dispatched
  batch.  Arrival times, sizes, payload bytes, scheduling decisions, sheds —
  every byte of the run is a deterministic function of the seed, which is
  what makes the p50/p95/p99 + goodput rows regression-gateable in CI.
* **Wall clock** (``replay_wallclock``): the same trace paced in real time
  against the real executables (dispatcher thread mode) — this is how the
  bench measures the actual cost of a cold cache vs an AOT-warmed one.

Traces are per-tenant Poisson processes (exponential inter-arrivals) with a
Zipfian request-size mix over a pow2 ladder, and ``zipf_shares`` skews the
tenant rate split for the "one hot tenant" overload scenarios.  All
randomness flows through ``numpy.random.default_rng(seed)`` — same seed,
byte-for-byte same trace and payloads (tests/test_frontend.py asserts it).
"""
from __future__ import annotations

import time
import zlib
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .scheduler import BatchInfo, ShedError, SortFrontend, Ticket

__all__ = [
    "Arrival",
    "LoadReport",
    "linear_service_time",
    "make_trace",
    "payload_for",
    "replay_wallclock",
    "run_load",
    "zipf_shares",
]

DEFAULT_SIZES = (256, 512, 1024, 2048, 4096)


@dataclass(frozen=True)
class Arrival:
    """One open-loop request: fires at ``t`` regardless of server state.

    >>> Arrival(t=0.25, tenant="web", size=1024, seq=3).size
    1024
    """

    t: float
    tenant: str
    size: int
    seq: int
    kind: str = "sort"


def zipf_shares(n: int, skew: float) -> Tuple[float, ...]:
    """Zipfian tenant shares: share_i ∝ (i+1)^-skew, normalized.

    ``skew=0`` is the uniform split; larger skew concentrates traffic on the
    first tenant — the "one hot tenant" overload shape.

    >>> [round(s, 3) for s in zipf_shares(3, 0.0)]
    [0.333, 0.333, 0.333]
    >>> shares = zipf_shares(3, 2.0)
    >>> shares[0] > 0.7 and abs(sum(shares) - 1.0) < 1e-12
    True
    """
    if n < 1:
        raise ValueError("need at least one tenant")
    raw = [(i + 1) ** -float(skew) for i in range(n)]
    total = sum(raw)
    return tuple(r / total for r in raw)


def make_trace(
    *,
    duration_s: float,
    rates: Dict[str, float],
    sizes: Sequence[int] = DEFAULT_SIZES,
    zipf_a: float = 1.2,
    seed: int = 0,
    kind: str = "sort",
) -> Tuple[Arrival, ...]:
    """Seeded open-loop trace: per-tenant Poisson arrivals, Zipfian sizes.

    ``rates`` maps tenant name -> mean arrivals/second; each tenant is an
    independent Poisson process (exponential inter-arrival times).  Request
    sizes are drawn from ``sizes`` with probability ∝ rank^-``zipf_a``
    (rank 1 = the first, most common size).  The merged trace is sorted by
    time with ``seq`` numbering arrival order — and it is a pure function of
    the arguments: same seed, byte-for-byte same trace.

    >>> tr = make_trace(duration_s=2.0, rates={"a": 5.0}, seed=7)
    >>> tr == make_trace(duration_s=2.0, rates={"a": 5.0}, seed=7)
    True
    >>> all(0 <= a.t <= 2.0 for a in tr)
    True
    """
    if duration_s <= 0:
        raise ValueError("duration_s must be positive")
    sizes = tuple(int(s) for s in sizes)
    ranks = np.arange(1, len(sizes) + 1, dtype=np.float64)
    probs = ranks ** -float(zipf_a)
    probs /= probs.sum()
    events: List[Arrival] = []
    # one independent, deterministically-derived stream per tenant, so adding
    # a tenant to the dict never perturbs another tenant's arrivals
    for tenant in sorted(rates):
        rate = float(rates[tenant])
        if rate < 0:
            raise ValueError(f"negative rate for tenant {tenant!r}")
        if rate == 0:
            continue
        # crc32, not hash(): str hashing is salted per process and would
        # break the same-seed byte-for-byte reproducibility contract
        rng = np.random.default_rng([seed, zlib.crc32(tenant.encode())])
        t = 0.0
        while True:
            t += float(rng.exponential(1.0 / rate))
            if t > duration_s:
                break
            size = int(rng.choice(sizes, p=probs))
            events.append(Arrival(t=t, tenant=tenant, size=size, seq=0,
                                  kind=kind))
    events.sort(key=lambda a: (a.t, a.tenant))
    return tuple(
        Arrival(t=a.t, tenant=a.tenant, size=a.size, seq=i, kind=a.kind)
        for i, a in enumerate(events)
    )


def payload_for(arrival: Arrival, *, seed: int = 0,
                dtype=np.int32) -> np.ndarray:
    """The request's key array — reproducible per (seed, arrival.seq).

    >>> a = Arrival(t=0.0, tenant="t", size=8, seq=5)
    >>> (payload_for(a, seed=1) == payload_for(a, seed=1)).all()
    True
    """
    rng = np.random.default_rng([seed, arrival.seq])
    return rng.integers(0, 1_000_000, arrival.size).astype(dtype)


def linear_service_time(
    *, base_ms: float = 0.2, us_per_key: float = 0.05
) -> Callable[[BatchInfo], float]:
    """A batched-server cost model: fixed dispatch cost + per-key cost.

    The fixed term is what batching amortizes — exactly the paper's
    fixed-cost story — so under this model a coalesced batch of n requests
    is cheaper than n singleton dispatches.

    >>> m = linear_service_time(base_ms=1.0, us_per_key=0.0)
    >>> m(BatchInfo(n_requests=4, bucket=1024, kind="sort", tenants=()))
    0.001
    """
    def model(info: BatchInfo) -> float:
        return base_ms / 1e3 + info.n_requests * info.bucket * us_per_key / 1e6
    return model


@dataclass
class LoadReport:
    """Outcome of one replayed trace: tickets, sheds, and derived metrics.

    ``goodput`` is the fraction of *offered* requests (admission sheds
    included — open-loop honesty) that completed within their deadline;
    ``latency_percentiles`` covers completed requests only.

    >>> LoadReport(offered=4, tickets=[], sheds=[("t", "global_backlog")]
    ...            ).goodput()
    0.0
    """

    offered: int = 0
    tickets: List[Ticket] = field(default_factory=list)
    sheds: List[Tuple[str, str]] = field(default_factory=list)  # (tenant, reason)
    elapsed_s: float = 0.0

    def _done(self, tenant: Optional[str]):
        return [
            t for t in self.tickets
            if t.latency_s is not None and not t.future.exception()
            and (tenant is None or t.tenant == tenant)
        ]

    def latency_percentiles(
        self, ps: Sequence[int] = (50, 95, 99), tenant: Optional[str] = None
    ) -> Dict[int, float]:
        """{percentile: seconds} over completed requests' submit->done time."""
        lat = sorted(t.latency_s for t in self._done(tenant))
        if not lat:
            return {p: 0.0 for p in ps}
        return {
            p: lat[min(len(lat) - 1, round(p / 100 * (len(lat) - 1)))]
            for p in ps
        }

    def goodput(self, tenant: Optional[str] = None) -> float:
        """Fraction of offered requests that completed within deadline."""
        if tenant is None:
            offered = self.offered
        else:
            offered = sum(1 for t in self.tickets if t.tenant == tenant) + sum(
                1 for tn, _ in self.sheds if tn == tenant
            )
        if not offered:
            return 0.0
        good = sum(1 for t in self._done(tenant) if t.slo_met)
        return good / offered

    def shed_counts(self, tenant: Optional[str] = None) -> Dict[str, int]:
        """reason -> count (optionally for one tenant)."""
        out: Dict[str, int] = {}
        for tn, reason in self.sheds:
            if tenant is None or tn == tenant:
                out[reason] = out.get(reason, 0) + 1
        return out

    def derived(self, tenant: Optional[str] = None) -> str:
        """The bench's machine-readable summary fragment."""
        pct = self.latency_percentiles((50, 95, 99), tenant)
        return (
            f"p50_ms={pct[50] * 1e3:.3f};p95_ms={pct[95] * 1e3:.3f};"
            f"p99_ms={pct[99] * 1e3:.3f};goodput={self.goodput(tenant):.3f};"
            f"shed={sum(self.shed_counts(tenant).values())}"
        )


def run_load(
    frontend: SortFrontend,
    trace: Sequence[Arrival],
    *,
    clock,
    service_time: Callable[[BatchInfo], float],
    seed: int = 0,
    dtype=np.int32,
    drain: bool = True,
) -> LoadReport:
    """Replay an open-loop trace as a deterministic discrete-event simulation.

    ``clock`` must be the same ``ManualClock`` the frontend was built on;
    ``service_time`` charges simulated seconds per dispatched batch.  The
    loop alternates the two event sources in time order: the server pumps
    whenever it is free before the next arrival (its finish time advances
    the clock), and each arrival fires at its trace time no matter how
    deep the backlog is — that is what "open-loop" means, and it is why
    overload here produces real queueing delay, sheds, and goodput loss.

    Expired-in-queue requests shed by the scheduler resolve their tickets
    with ``ShedError('deadline')`` and are folded into the report's shed
    ledger alongside admission refusals.

    >>> from repro.engine.adapt import ManualClock
    >>> from repro.engine.frontend import SortFrontend, Tenant
    >>> clk = ManualClock()
    >>> fe = SortFrontend(tenants=[Tenant("t")], clock=clk)
    >>> tr = make_trace(duration_s=0.3, rates={"t": 20.0}, sizes=(64,), seed=3)
    >>> rep = run_load(fe, tr, clock=clk,
    ...                service_time=linear_service_time(base_ms=0.1))
    >>> rep.offered == len(tr) and 0.0 <= rep.goodput() <= 1.0
    True
    """
    report = LoadReport(offered=len(trace))
    free_at = clock()
    i = 0
    while i < len(trace) or (frontend.backlog() and drain):
        next_t = trace[i].t if i < len(trace) else float("inf")
        if frontend.backlog() and free_at <= next_t:
            if free_at > clock():
                clock.advance(free_at - clock())
            info = frontend.pump()
            if info is not None and info.n_requests:
                free_at = clock() + service_time(info)
            continue
        if i >= len(trace):
            break
        arr = trace[i]
        i += 1
        if arr.t > clock():
            clock.advance(arr.t - clock())
        free_at = max(free_at, clock())
        try:
            report.tickets.append(
                frontend.submit(arr.tenant, payload_for(arr, seed=seed,
                                                        dtype=dtype),
                                kind=arr.kind)
            )
        except ShedError as e:
            report.sheds.append((e.tenant, e.reason))
    # dispatch-time deadline sheds also live on tickets; mirror them into
    # the shed ledger so shed_counts sees both admission and expiry
    for t in report.tickets:
        exc = t.future.exception() if t.done() else None
        if isinstance(exc, ShedError):
            report.sheds.append((exc.tenant, exc.reason))
    report.elapsed_s = clock()
    return report


def replay_wallclock(
    frontend: SortFrontend,
    trace: Sequence[Arrival],
    *,
    seed: int = 0,
    dtype=np.int32,
    timeout_s: float = 120.0,
) -> LoadReport:
    """Replay a trace in real time against the real executables.

    The frontend must be running its dispatcher thread (``start()``).
    Arrival pacing sleeps until each trace time; latencies come from the
    frontend's (real) clock stamps.  This is the bench's warm-vs-cold mode:
    the cold run's percentiles include first-request compile stalls, the
    AOT-warmed run's do not.
    """
    report = LoadReport(offered=len(trace))
    t0 = time.perf_counter()
    for arr in trace:
        lag = arr.t - (time.perf_counter() - t0)
        if lag > 0:
            time.sleep(lag)
        try:
            report.tickets.append(
                frontend.submit(arr.tenant, payload_for(arr, seed=seed,
                                                        dtype=dtype),
                                kind=arr.kind)
            )
        except ShedError as e:
            report.sheds.append((e.tenant, e.reason))
    deadline = time.perf_counter() + timeout_s
    for t in report.tickets:
        try:
            t.future.result(timeout=max(0.0, deadline - time.perf_counter()))
        except Exception:
            pass  # sheds/errors are accounted below, not raised here
        exc = t.future.exception()
        if isinstance(exc, ShedError):
            report.sheds.append((exc.tenant, exc.reason))
    report.elapsed_s = time.perf_counter() - t0
    return report
