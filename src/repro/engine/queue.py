"""Async serving front door — cross-caller micro-batching over ``SortService``.

The sync ``SortService.submit`` only batches requests that arrive *in the
same call*, so callers must hand-assemble well-shaped batches to amortize
fixed costs — exactly the shape the paper says dominates parallel sort
throughput.  ``AsyncSortService`` moves that batching behind the API:
producers on any thread call ``submit_async`` with a single request and get
a ``concurrent.futures.Future``; one dispatcher thread coalesces requests
**across callers** into per-(kind, direction, length-bucket, dtype[, value
signature]) micro-batches under a ``max_batch`` / ``max_delay_ms`` policy and
executes each batch through ``SortService._run_group`` — the same
pad/plan/execute core the sync path uses, so the steady state stays
zero-recompile and every compiled executable is shared between both paths.

Backpressure is a bounded stdlib queue: ``maxsize`` caps admitted-but-unrun
requests; ``on_full='block'`` makes producers wait for room while
``on_full='reject'`` raises ``queue.Full`` at the call site.  ``drain()``
blocks until everything admitted has resolved; ``close()`` drains, stops the
dispatcher, and rejects later submits (also the context-manager exit path).

``QueueStats`` extends ``ServiceStats`` with queue-level telemetry: batch
fill ratio, coalesced-batch sizes, and rolling queue-latency percentiles.
See docs/serving.md for the request lifecycle.

Timing is injectable: every batching decision (enqueue stamps, flush
deadlines, queue latencies) reads the ``clock`` passed at construction
(``time.monotonic`` by default; ``repro.engine.adapt.ManualClock`` in
tests), and passing ``min_delay_ms`` turns the fixed flush window into a
``DelayController``-adapted one — shrink when batches fill before the
deadline, grow when they flush sparse, always within
``[min_delay_ms, max_delay_ms]``.
"""
from __future__ import annotations

import queue as _stdqueue
import threading
import time
from collections import deque
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

from .adapt import DelayController
from .planner import Planner
from .service import ServiceStats, SortService

__all__ = ["AsyncSortService", "QueueStats"]


@dataclass
class QueueStats(ServiceStats):
    """``ServiceStats`` plus micro-batching telemetry for the async queue.

    ``fill_ratios`` / ``batch_sizes`` / ``queue_latency_s`` are rolling
    windows (bounded deques), so a long-lived service reports recent steady
    state rather than lifetime averages.

    >>> s = QueueStats()
    >>> s.observe_batch(n_requests=6, capacity=8, latencies=[0.002] * 6)
    >>> round(s.fill_ratio(), 2)
    0.75
    >>> s.latency_percentiles()[50]
    0.002
    """

    enqueued: int = 0
    rejected: int = 0
    coalesced_batches: int = 0
    coalesced_requests: int = 0
    fill_ratios: deque = field(default_factory=lambda: deque(maxlen=1024), repr=False)
    batch_sizes: deque = field(default_factory=lambda: deque(maxlen=1024), repr=False)
    queue_latency_s: deque = field(
        default_factory=lambda: deque(maxlen=8192), repr=False
    )
    # multi-tenant accounting (repro.engine.frontend): every load-shed is
    # attributed to the tenant that suffered it and the reason it fired, and
    # every served request lands in its tenant's tally — overload debugging
    # starts from "who was shed, and why", not from a global counter
    shed: Dict[str, Dict[str, int]] = field(default_factory=dict, repr=False)
    tenant_served: Dict[str, int] = field(default_factory=dict, repr=False)

    def observe_shed(self, tenant: str, reason: str) -> None:
        """Attribute one load-shed to ``tenant`` with its ``reason``
        (``'tenant_backlog'`` / ``'global_backlog'`` / ``'deadline'``)."""
        self.rejected += 1
        per = self.shed.setdefault(tenant, {})
        per[reason] = per.get(reason, 0) + 1

    def shed_total(self, tenant: Optional[str] = None) -> int:
        """Total sheds — for one tenant, or across all tenants."""
        tenants = [tenant] if tenant is not None else list(self.shed)
        return sum(sum(self.shed.get(t, {}).values()) for t in tenants)

    def observe_batch(self, *, n_requests: int, capacity: int, latencies) -> None:
        """Record one executed micro-batch (size, fill vs ``max_batch``, and
        each member request's time-in-queue)."""
        self.coalesced_batches += 1
        self.coalesced_requests += n_requests
        self.batch_sizes.append(n_requests)
        self.fill_ratios.append(n_requests / capacity if capacity else 0.0)
        self.queue_latency_s.extend(latencies)

    def fill_ratio(self) -> float:
        """Mean batch-fill ratio (requests per batch / max_batch) over the
        rolling window; 0.0 before any batch has run."""
        if not self.fill_ratios:
            return 0.0
        return sum(self.fill_ratios) / len(self.fill_ratios)

    def latency_percentiles(self, ps=(50, 90, 99)) -> Dict[int, float]:
        """{percentile: seconds} over the rolling queue-latency window
        (time from ``submit_async`` to batch execution start)."""
        lat = sorted(self.queue_latency_s)
        if not lat:
            return {p: 0.0 for p in ps}
        return {
            p: lat[min(len(lat) - 1, round(p / 100 * (len(lat) - 1)))] for p in ps
        }


class _Request:
    """One admitted request riding the queue to its micro-batch."""

    __slots__ = ("key", "req", "val", "future", "t_enq")

    def __init__(self, key, req, val, t_enq):
        self.key = key
        self.req = req
        self.val = val
        self.future: Future = Future()
        self.t_enq = t_enq


class AsyncSortService:
    """Micro-batching async front door over a ``SortService``.

    Parameters
    ----------
    service:      the ``SortService`` to execute on (shares its compiled-
                  executable cache with sync callers); a fresh one by default.
    max_batch:    flush a (kind, bucket, dtype) group as soon as it holds this
                  many requests.
    max_delay_ms: flush a group at latest this long after its *oldest* request
                  arrived — the latency bound a half-empty batch waits for.
    min_delay_ms: opt into the adaptive flush window: a ``DelayController``
                  moves the effective delay within
                  ``[min_delay_ms, max_delay_ms]`` from observed fill
                  (``None`` = fixed window, the prior behaviour).
    maxsize:      bound on admitted-but-unexecuted requests (0 = unbounded).
    on_full:      'block' stalls producers while the queue is full;
                  'reject' raises ``queue.Full`` at the ``submit_async`` site.
    start:        launch the dispatcher thread immediately (tests pass False
                  to stage traffic deterministically, then call ``start()``).
    clock:        monotonic time source for every batching decision — enqueue
                  stamps, flush deadlines, latencies, delay adaptation.
                  Inject ``repro.engine.adapt.ManualClock`` to make queue
                  timing fully deterministic in tests.

    >>> import numpy as np
    >>> with AsyncSortService(max_batch=4, max_delay_ms=5.0) as svc:
    ...     futs = [svc.submit_async(np.array([3, 1, 2], np.int32))
    ...             for _ in range(4)]
    ...     sorted_first = [int(v) for v in futs[0].result()]
    >>> sorted_first
    [1, 2, 3]
    """

    def __init__(
        self,
        service: Optional[SortService] = None,
        *,
        max_batch: int = 64,
        max_delay_ms: float = 2.0,
        min_delay_ms: Optional[float] = None,
        maxsize: int = 1024,
        on_full: str = "block",
        start: bool = True,
        planner: Optional[Planner] = None,
        clock: Callable[[], float] = time.monotonic,
    ):
        if on_full not in ("block", "reject"):
            raise ValueError("on_full must be 'block' or 'reject'")
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.service = service if service is not None else SortService(planner=planner)
        # widen the service's counters in place: _run_group keeps accounting
        # into the same object, so sync and async traffic share one ledger
        if not isinstance(self.service.stats, QueueStats):
            self.service.stats = QueueStats(**vars(self.service.stats))
        self.max_batch = int(max_batch)
        self.max_delay_s = float(max_delay_ms) / 1e3
        self._clock = clock
        self.delay: Optional[DelayController] = (
            None
            if min_delay_ms is None
            else DelayController(float(min_delay_ms), float(max_delay_ms), clock=clock)
        )
        self.on_full = on_full
        self._q: _stdqueue.Queue = _stdqueue.Queue(maxsize=maxsize)
        self._pending: Dict[tuple, List[_Request]] = {}
        self._deadlines: Dict[tuple, float] = {}
        self._outstanding = 0
        self._admitting = 0  # submits between their closed-check and their put
        self._done = threading.Condition()
        self._closed = False
        self._stop = threading.Event()
        self._started = False
        self._thread = threading.Thread(
            target=self._dispatch_loop, name="AsyncSortService", daemon=True
        )
        if start:
            self.start()

    # ----------------------------------------------------------- lifecycle ---
    @property
    def stats(self) -> QueueStats:
        """The shared (sync + async) ``QueueStats`` ledger."""
        return self.service.stats

    def start(self) -> "AsyncSortService":
        """Launch the dispatcher thread (idempotent)."""
        if not self._started:
            self._started = True
            self._thread.start()
        return self

    @property
    def delay_s(self) -> float:
        """The effective coalescing window: the controller's current value
        when adaptive, else the fixed ``max_delay_ms``."""
        return self.delay.delay_s if self.delay is not None else self.max_delay_s

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until every admitted request has resolved (or ``timeout``
        wall-clock seconds elapse — real time even under an injected clock,
        so a frozen test clock can't hang a drain forever). Returns True
        when fully drained."""
        deadline = None if timeout is None else time.perf_counter() + timeout
        with self._done:
            while self._outstanding > 0:
                wait = None if deadline is None else deadline - time.perf_counter()
                if wait is not None and wait <= 0:
                    return False
                self._done.wait(timeout=wait)
        return True

    def close(self, *, drain: bool = True) -> None:
        """Stop accepting requests; optionally drain, then join the
        dispatcher. Idempotent; later ``submit_async`` raises RuntimeError.

        The stop signal is raised *before* draining so the dispatcher flushes
        half-empty batches immediately instead of waiting out ``max_delay``.
        """
        with self._done:
            self._closed = True
            # wait for submits that passed the closed-check to land their
            # put — after this, the queue's contents are final and the
            # dispatcher (which only exits once the queue is empty) will
            # serve every admitted request before stopping
            while self._admitting > 0:
                self._done.wait()
        self._stop.set()
        if drain:
            self.start()  # a never-started service must still resolve backlog
            self.drain()
        if self._started:
            self._thread.join(timeout=30)
        # belt-and-braces: fail anything somehow still queued after the
        # dispatcher has exited rather than strand its future
        while True:
            try:
                item = self._q.get_nowait()
            except _stdqueue.Empty:
                break
            if item.future.set_running_or_notify_cancel():
                item.future.set_exception(RuntimeError("AsyncSortService is closed"))
            self._mark_done(1)

    def __enter__(self) -> "AsyncSortService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # -------------------------------------------------------------- submit ---
    def submit_async(
        self,
        keys: np.ndarray,
        *,
        kind: str = "sort",
        values: Optional[np.ndarray] = None,
        ascending: bool = True,
    ) -> Future:
        """Enqueue one 1-D request; returns a Future of the same per-request
        result ``SortService.submit`` would produce (sorted keys, argsort
        indices, or a (keys, values) pair for kind='sort_kv').

        Validation errors raise here, synchronously, on the caller's thread;
        execution errors resolve the Future exceptionally.  With
        ``on_full='reject'`` a full queue raises ``queue.Full``.
        """
        reqs, vals = self.service._validate(
            kind, [keys], [values] if values is not None else None
        )
        # snapshot the caller's buffers: the dispatcher pads them up to
        # max_delay_ms later, and an async caller may legitimately reuse or
        # mutate its array the moment submit_async returns
        req = np.array(reqs[0], copy=True)
        val = np.array(vals[0], copy=True) if vals is not None else None
        gk = self.service._group_key(req, val)
        item = _Request((kind, bool(ascending)) + gk, req, val, self._clock())
        # the closed-check and the admission counter are one atom with
        # respect to close(): close() flips _closed under this lock, then
        # waits for in-flight admissions to land their put before it lets
        # the dispatcher exit — so no put can strand behind a dead dispatcher
        with self._done:
            if self._closed:
                raise RuntimeError("AsyncSortService is closed")
            self._admitting += 1
            self._outstanding += 1
            self.stats.enqueued += 1
        try:
            self._q.put(item, block=self.on_full == "block")
        except _stdqueue.Full:
            with self._done:
                self._outstanding -= 1
                self.stats.enqueued -= 1
                self.stats.rejected += 1
            raise
        finally:
            with self._done:
                self._admitting -= 1
                self._done.notify_all()
        # re-stamp at admission: a producer that sat out a blocking put must
        # not carry a pre-expired flush deadline into the dispatcher (the
        # coalescing window starts when coalescing *can* start). Benign race:
        # if the dispatcher already grabbed the item, it saw the submit-time
        # stamp — a slightly early deadline, never a stuck one.
        item.t_enq = self._clock()
        # only admitted requests count as arrivals: rejected/closed submits
        # must not inflate the adaptive controller's rate estimate
        if self.delay is not None:
            self.delay.note_arrival()
        return item.future

    # ---------------------------------------------------------- dispatcher ---
    def _dispatch_loop(self) -> None:
        poll = 0.05
        while not (self._stop.is_set() and self._q.empty() and not self._pending):
            wait = poll
            if self._pending:
                now = self._clock()
                wait = max(0.0, min(min(self._deadlines.values()) - now, poll))
            try:
                items = [self._q.get(timeout=wait)]
            except _stdqueue.Empty:
                items = []
            # drain everything already admitted before looking at deadlines:
            # requests that queued up while a batch was executing must join
            # one group, not flush as a string of expired singletons
            while True:
                try:
                    items.append(self._q.get_nowait())
                except _stdqueue.Empty:
                    break
            for item in items:
                group = self._pending.setdefault(item.key, [])
                group.append(item)
                # the deadline snapshots the *current* adaptive window when
                # the group opens, so one flush decision uses one delay value
                self._deadlines.setdefault(item.key, item.t_enq + self.delay_s)
                if len(group) >= self.max_batch:
                    self._flush(item.key, cause="full")
            now = self._clock()
            for key in [k for k, d in self._deadlines.items() if d <= now]:
                self._flush(key, cause="deadline")
            if self._stop.is_set() and self._q.empty():
                for key in list(self._pending):
                    self._flush(key, cause="close")
        for key in list(self._pending):  # safety: never strand a future
            self._flush(key, cause="close")

    def _flush(self, key: tuple, *, cause: str = "deadline") -> None:
        all_items = self._pending.pop(key, [])
        self._deadlines.pop(key, None)
        # a caller-cancelled future must neither run nor poison set_result
        items = [it for it in all_items if it.future.set_running_or_notify_cancel()]
        if len(items) < len(all_items):
            self._mark_done(len(all_items) - len(items))
        if not items:
            return
        if self.delay is not None and cause != "close":
            # adapt the window to what this flush revealed; lifecycle
            # flushes at close say nothing about the arrival process
            self.delay.observe_flush(
                n_requests=len(items),
                capacity=self.max_batch,
                deadline_hit=cause == "deadline",
            )
        kind, ascending = key[0], key[1]
        reqs = [it.req for it in items]
        vals = [it.val for it in items] if kind == "sort_kv" else None
        t_exec = self._clock()
        try:
            results = self.service._run_group(
                kind, key[2:], reqs, vals, ascending=ascending
            )
        except Exception as e:  # execution failure -> every member future
            for it in items:
                it.future.set_exception(e)
            self._mark_done(len(items))
            return
        with self.service._lock:
            self.stats.observe_batch(
                n_requests=len(items),
                capacity=self.max_batch,
                latencies=[t_exec - it.t_enq for it in items],
            )
        for it, res in zip(items, results):  # arrival order within the batch
            it.future.set_result(res)
        self._mark_done(len(items))

    def _mark_done(self, n: int) -> None:
        with self._done:
            self._outstanding -= n
            self._done.notify_all()
