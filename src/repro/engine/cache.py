"""Compiled-plan cache: pow2 shape bucketing so serving traffic never re-traces.

Serving requests arrive with ragged lengths; tracing/compiling an XLA
executable per exact shape would dominate latency.  Instead every request is
padded to its power-of-two *bucket* (tail filled with sort sentinels, so the
valid prefix of the sorted output is exactly the answer) and one ahead-of-time
compiled executable is kept per (kind, bucket shape, dtype, plan) key.  After
warmup, a submit is a pure numpy pad + one AOT executable call — zero jax
tracing or lowering on the hot path.

The cluster (model D) path has its own compiled cache keyed on slab capacity
— which is why capacity learning (repro.engine.adapt) matters: a learned
``capacity_factor`` means the steady-state capacity is known at the first
call, so overflow retries never force fresh compilations there either.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Tuple

import jax

from repro.core.bitonic import next_pow2

__all__ = ["size_bucket", "CompiledCache"]


def size_bucket(n: int, *, min_bucket: int = 8) -> int:
    """Pad target for a length-n request (pow2, floored at min_bucket).

    >>> size_bucket(1000)
    1024
    >>> size_bucket(3)
    8
    """
    return max(min_bucket, next_pow2(n))


@dataclass
class CompiledCache:
    """key -> AOT-compiled executable, with hit/miss (=compile) counters.

    The key is the caller's full executable identity — for the sort service
    that includes the plan's ``local_impl`` *and* ``block_n``, since a pallas
    plan with a different tile width is a different traced program.

    >>> import jax, jax.numpy as jnp
    >>> cache = CompiledCache()
    >>> exe = cache.get_or_build(
    ...     ("double", 3),
    ...     lambda: (lambda v: v * 2),
    ...     [jax.ShapeDtypeStruct((3,), jnp.int32)],
    ... )
    >>> [int(v) for v in exe(jnp.array([1, 2, 3]))]
    [2, 4, 6]
    >>> cache.stats()
    {'entries': 1, 'hits': 0, 'misses': 1}
    """

    executables: Dict[Tuple, Any] = field(default_factory=dict)
    hits: int = 0
    misses: int = 0

    def get_or_build(self, key: Tuple, build: Callable[[], Callable], example_args):
        """Return the executable for ``key``; trace+compile it on first use.

        ``build()`` returns the traceable python callable; ``example_args``
        are ShapeDtypeStructs (or arrays) fixing the input signature.
        """
        exe = self.executables.get(key)
        if exe is not None:
            self.hits += 1
            return exe
        self.misses += 1
        exe = jax.jit(build()).lower(*example_args).compile()
        self.executables[key] = exe
        return exe

    def __contains__(self, key: Tuple) -> bool:
        """Is ``key``'s executable already compiled (warm)?

        >>> CompiledCache().__contains__(("sort", 8))
        False
        """
        return key in self.executables

    def keys(self):
        """The compiled cells, in insertion (= warmup/serve) order — what an
        AOT ``warmup`` pass has actually made hot."""
        return list(self.executables)

    def stats(self) -> dict:
        return {
            "entries": len(self.executables),
            "hits": self.hits,
            "misses": self.misses,
        }
