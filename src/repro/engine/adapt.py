"""Closed-loop adaptive tuning — learn knobs from observed runtime behaviour.

Two feedback loops, both deterministic and clock-injectable:

**Capacity learning** (model D *and* MoE dispatch).  Without it, every
exchange call re-learns slab capacity the hard way: overflow, double
``capacity_factor``, recompile, retry (or, on the MoE fixed path, drop
tokens) — then throws the lesson away.  Here every call reports an
``ExchangeObservation`` (max observed per-(src, dst) bucket count,
overflow/retry/recompile/drop events — the schema lives in
``repro.exchange.telemetry``) into an ``ExchangeTelemetry`` ledger keyed by
plan-cache cell, and a ``CapacityLearner`` folds the history into a learned
``capacity_factor``: jump to ``observed peak x safety margin`` the moment a
call needs more than the current factor, decay geometrically back toward
the default while traffic stays mild.  The ``Planner`` persists the learned
factors through its JSON plan cache, so a restarted serving process sizes
slabs (and expert token buffers) right on the **first** compile — zero
overflow-retry recompiles in steady state.

**Adaptive flush window** (async serving).  ``DelayController`` owns the
``AsyncSortService`` coalescing deadline: it tracks rolling arrival rate
and per-flush fill ratio, shrinks the window when batches fill before the
deadline (the queue is adding latency for no extra fill), and grows it when
deadline flushes run sparse (a longer wait would amortize better) — always
within ``[min_delay_ms, max_delay_ms]``.

Every decision consumes an injectable monotonic ``clock`` (``ManualClock``
for tests), so adaptation is reproducible step by step — no wall-clock
dependence anywhere in the loop.  See docs/serving.md and
docs/plan-cache.md for how the pieces wire together.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import asdict, dataclass
from typing import Callable, Optional

from repro.exchange import ExchangeObservation, ExchangeTelemetry  # noqa: F401
# ^ the observation schema + ledger live in the unified exchange layer now
#   (repro.exchange.telemetry); re-exported here because this module is where
#   the learning loop's consumers historically imported them from.

__all__ = [
    "CapacityLearner",
    "DelayController",
    "ExchangeObservation",
    "ExchangeTelemetry",
    "LearnedCapacity",
    "ManualClock",
]


class ManualClock:
    """Deterministic monotonic clock for tests and doctests.

    Inject it wherever a ``clock=`` is accepted; time only moves when the
    test calls ``advance``, so every timing decision replays exactly.

    >>> clock = ManualClock()
    >>> clock()
    0.0
    >>> clock.advance(1.5)
    1.5
    >>> clock()
    1.5
    """

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        """Move time forward by ``dt`` seconds (never backward)."""
        if dt < 0:
            raise ValueError("a monotonic clock cannot go backward")
        self.t += dt
        return self.t


@dataclass(frozen=True)
class LearnedCapacity:
    """One plan-cache cell's learned capacity state (persisted as JSON).

    >>> LearnedCapacity.from_dict(
    ...     LearnedCapacity(3.75, 3.0, 7).to_dict()).capacity_factor
    3.75
    """

    capacity_factor: float   # the factor the planner now hands out
    peak_factor: float       # largest required_factor ever observed (audit)
    observations: int        # how many calls fed this cell
    partition: Optional[str] = None  # promoted partition family ("sample"
    #                                  once skew promotion latches; None =
    #                                  follow the plan's own mode)
    skew_strikes: int = 0    # consecutive high-skew radix observations —
    #                          the promotion counter (resets on a calm call)
    calm_streak: int = 0     # consecutive calm sample-era observations on a
    #                          promoted cell — the slow probation counter
    #                          that eventually demotes it back to radix
    demotions: int = 0       # how many times this cell has been demoted —
    #                          a generation counter that makes demotion
    #                          survive merges with stale promoted entries

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "LearnedCapacity":
        return cls(
            capacity_factor=float(d["capacity_factor"]),
            peak_factor=float(d.get("peak_factor", 0.0)),
            observations=int(d.get("observations", 0)),
            partition=d.get("partition"),
            skew_strikes=int(d.get("skew_strikes", 0)),
            calm_streak=int(d.get("calm_streak", 0)),
            demotions=int(d.get("demotions", 0)),
        )

    def merge(self, other: "LearnedCapacity") -> "LearnedCapacity":
        """Combine two entries for the same cell from concurrent writers.

        The **more-informed lineage wins** the factor: lexicographic max on
        ``(observations, capacity_factor)``.  ``observations`` grows
        monotonically within one planner's lineage, so a writer always
        supersedes its *own* earlier persisted state — geometric decay back
        toward the default survives the merge instead of being pinned by a
        stale high-water entry.  Between genuinely concurrent writers the
        one that has seen more traffic wins, and at equal observation counts
        the higher (more conservative) factor does — under-provisioning is
        the expensive error.  ``peak_factor`` is a lifetime max by
        definition, and ``observations`` takes max rather than sum because
        concurrent counts share lineage through the persisted file — summing
        would double-count on every merge.  The partition state merges as a
        lexicographic max on ``(demotions, partition rank)`` where rank is
        ``None < "radix" < "sample"``: *within one demotion generation* the
        promotion latch is monotone — a concurrent writer that hasn't seen
        the skew yet can't demote a promoted cell — while an explicit
        calm-streak demotion bumps ``demotions`` and therefore wins over
        every stale promoted entry from the previous generation (a laggard
        writer re-saving its old ``partition="sample"`` cannot flap a
        demoted cell back).  ``skew_strikes``/``calm_streak`` take max for
        the same shared-lineage reason as ``observations``.  All components
        are commutative, associative, and idempotent, so any interleaving of
        rank saves converges to the same entry (property-tested in
        tests/test_plan_cache_concurrency.py).

        >>> LearnedCapacity(3.0, 2.5, 4).merge(LearnedCapacity(2.0, 3.0, 9))
        ... # doctest: +NORMALIZE_WHITESPACE
        LearnedCapacity(capacity_factor=2.0, peak_factor=3.0, observations=9,
                        partition=None, skew_strikes=0, calm_streak=0,
                        demotions=0)
        >>> e = LearnedCapacity(3.0, 2.5, 9).merge(LearnedCapacity(2.0, 3.0, 9))
        >>> e.capacity_factor                    # tie on observations: higher
        3.0
        >>> LearnedCapacity(2.0, 2.0, 1, partition="sample").merge(
        ...     LearnedCapacity(9.0, 9.0, 9)).partition   # promotion latches
        'sample'
        >>> LearnedCapacity(2.0, 2.0, 9, demotions=1).merge(   # a demotion
        ...     LearnedCapacity(2.0, 2.0, 1, partition="sample")   # is a newer
        ... ).partition is None          # generation: stale promotion loses
        True
        """
        a, b = (self.observations, self.capacity_factor), (
            other.observations,
            other.capacity_factor,
        )
        win = self if a >= b else other
        rank = {None: 0, "radix": 1, "sample": 2}
        ps = (self.demotions, rank.get(self.partition, 0))
        po = (other.demotions, rank.get(other.partition, 0))
        if ps == po:  # same generation + family: counters share lineage
            part, demotions = self.partition, self.demotions
            strikes = max(self.skew_strikes, other.skew_strikes)
            calm = max(self.calm_streak, other.calm_streak)
        else:  # newer generation (or higher latch within it) wins outright
            src = self if ps > po else other
            part, demotions = src.partition, src.demotions
            strikes, calm = src.skew_strikes, src.calm_streak
        return LearnedCapacity(
            capacity_factor=win.capacity_factor,
            peak_factor=max(self.peak_factor, other.peak_factor),
            observations=max(self.observations, other.observations),
            partition=part,
            skew_strikes=strikes,
            calm_streak=calm,
            demotions=demotions,
        )


@dataclass(frozen=True)
class CapacityLearner:
    """Capacity-factor policy: jump up on pressure, decay toward default.

    For each observation the *target* factor is the observed requirement
    times ``margin`` (clamped to ``[default, max_factor]``).  A target at or
    above the current learned factor is adopted immediately — overflow costs
    a retry and a recompile, so under-provisioning is the expensive error.
    A lower target decays the learned factor geometrically toward the
    default, never dropping below the target itself, so one burst of skew
    doesn't pin peak slab memory forever.

    Invariants (property-tested in tests/test_adapt.py): the learned factor
    always stays within ``[default, max_factor]`` and never exceeds the
    largest ``target`` the history produced — it cannot oscillate past
    observed peak x margin.

    >>> lrn = CapacityLearner(margin=1.25, decay=0.5)
    >>> obs = ExchangeObservation(m=128, part_buckets=8, capacity=32,
    ...                           peak=48, overflowed=True, retries=1)
    >>> cf = lrn.update(2.0, obs, default=2.0)   # 3.0 required -> 3.75
    >>> cf
    3.75
    >>> calm = ExchangeObservation(m=128, part_buckets=8, capacity=60,
    ...                            peak=16, overflowed=False, retries=0)
    >>> lrn.update(cf, calm, default=2.0)        # halfway back toward 2.0
    2.875

    **Skew promotion** (radix -> sample partition).  Headroom absorbs skew
    but never removes it: a persistently skewed key distribution keeps a
    radix-partitioned cell's capacity factor pinned high forever.  The
    learner therefore also counts *consecutive* radix observations whose
    peak/mean bucket ratio exceeds ``promote_ratio``; at ``promote_after``
    strikes the planner latches the cell's learned ``partition`` to
    ``"sample"`` — subsequent calls partition by balanced composite
    splitters, the ratio drops to ~1, and the capacity factor decays back
    toward the default.  Sample-partition (and untagged, e.g. MoE)
    observations never accrue strikes; one calm radix call resets them.

    >>> skewed = ExchangeObservation(m=128, part_buckets=8, capacity=64,
    ...     peak=64, overflowed=True, retries=1, partition="radix")
    >>> s = lrn.promotion_strikes(0, skewed); s      # ratio 4.0 > 2.0
    1
    >>> lrn.should_promote(lrn.promotion_strikes(2, skewed))
    True
    >>> lrn.promotion_strikes(2, calm)               # untagged: unchanged
    2

    **Probation / demotion** (sample -> radix, slowly).  Promotion is no
    longer a one-way latch: ``calm_streak`` counts consecutive calm
    sample-era observations on a promoted cell, and once the streak
    outlasts ``demote_threshold`` (``demote_after`` doubled per prior
    demotion) the planner demotes the cell back to its radix-family plan —
    with the ``demotions`` generation counter bumped so the decision
    survives merges with stale promoted entries (see
    ``LearnedCapacity.merge``).  If the skew returns during probation, the
    normal three-strike promotion re-latches, now one generation up.
    """

    margin: float = 1.25
    decay: float = 0.5
    max_factor: float = 64.0
    snap_eps: float = 1e-3
    promote_ratio: float = 2.0
    promote_after: int = 3
    demote_ratio: float = 1.5
    demote_after: int = 32

    def target(self, obs: ExchangeObservation, *, default: float) -> float:
        """observed requirement x margin, clamped to [default, max_factor]."""
        return min(self.max_factor, max(default, obs.required_factor() * self.margin))

    def update(
        self, learned: float, obs: ExchangeObservation, *, default: float
    ) -> float:
        t = self.target(obs, default=default)
        if t >= learned:
            return t
        # geometric decay toward default, floored at the current target so a
        # steady skew level holds its learned factor instead of oscillating;
        # within snap_eps of the default the decay lands exactly on it, so
        # the walk terminates (and stops dirtying the persisted plan cache) —
        # guarded on t == default so the snap can never undershoot a target
        decayed = max(t, default + (learned - default) * self.decay)
        if t <= default and decayed - default < self.snap_eps:
            return default
        return decayed

    def promotion_strikes(self, strikes: int, obs: ExchangeObservation) -> int:
        """Fold one observation into the skew-strike counter.

        Only ``partition="radix"`` observations participate: a high-ratio
        one adds a strike, a calm one resets to zero (the skew must be
        *persistent* to promote).  Sample-partition and untagged
        observations pass the counter through unchanged — promotion is a
        judgement about radix behaviour, and e.g. MoE routing skew must not
        flip a sort cell's partition.  *Empty* observations (``m == 0``:
        an idle tick or a drained shard) also pass through — their
        ``peak_mean_ratio`` is 0.0 by construction, which says nothing
        about the distribution, so treating them as "calm" would reset
        the counter for a genuinely skewed cell.

        >>> lrn = CapacityLearner()
        >>> empty = ExchangeObservation(m=0, part_buckets=8, capacity=1,
        ...     peak=0, overflowed=False, retries=0, partition="radix")
        >>> lrn.promotion_strikes(2, empty)          # not evidence of calm
        2
        """
        if obs.partition != "radix" or obs.m == 0:
            return strikes
        if obs.peak_mean_ratio() > self.promote_ratio:
            return strikes + 1
        return 0

    def should_promote(self, strikes: int) -> bool:
        """True once the strike counter reaches ``promote_after``."""
        return strikes >= self.promote_after

    def calm_streak(self, streak: int, obs: ExchangeObservation) -> int:
        """Fold one observation into the slow probation counter.

        The promotion latch used to be one-way by design: once a cell ran
        the sample partition, nothing could ever send it back to the faster
        radix family even if the skew that caused the promotion vanished.
        The probation counter is the way back: *consecutive* calm
        sample-partition observations (peak/mean at or below
        ``demote_ratio``, no overflow) accrue; an overflowing or skewed
        sample call resets to zero (the distribution is still rough).
        Radix, untagged (MoE), and empty (``m == 0``) observations pass the
        counter through unchanged — they say nothing about the promoted
        cell's calm.

        >>> lrn = CapacityLearner()
        >>> calm = ExchangeObservation(m=128, part_buckets=8, capacity=32,
        ...     peak=16, overflowed=False, retries=0, partition="sample")
        >>> lrn.calm_streak(4, calm)
        5
        >>> rough = ExchangeObservation(m=128, part_buckets=8, capacity=32,
        ...     peak=48, overflowed=True, retries=1, partition="sample")
        >>> lrn.calm_streak(4, rough)
        0
        >>> lrn.calm_streak(4, ExchangeObservation(m=0, part_buckets=8,
        ...     capacity=1, peak=0, overflowed=False, retries=0,
        ...     partition="sample"))                  # idle tick: no evidence
        4
        """
        if obs.partition != "sample" or obs.m == 0:
            return streak
        if obs.peak_mean_ratio() <= self.demote_ratio and not obs.overflowed:
            return streak + 1
        return 0

    def demote_threshold(self, demotions: int = 0) -> int:
        """Calm observations required before the next demotion.

        Doubles with every demotion the cell has already been through
        (capped at 2^16): a cell whose skew keeps coming back spends
        exponentially longer on the sample partition before each new
        probation attempt — the counter is *slow* by design, so promotion
        and demotion can never flap call-to-call.

        >>> lrn = CapacityLearner()
        >>> (lrn.demote_threshold(0), lrn.demote_threshold(2))
        (32, 128)
        """
        return self.demote_after * (2 ** min(demotions, 16))

    def should_demote(self, streak: int, demotions: int = 0) -> bool:
        """True once the calm streak has outlasted this generation's
        probation threshold."""
        return streak >= self.demote_threshold(demotions)


class DelayController:
    """Adaptive coalescing window for ``AsyncSortService``.

    Owns the effective ``max_delay`` within ``[min_delay_ms, max_delay_ms]``:
    a batch that fills to ``capacity`` *before* its deadline shrinks the
    window (waiting longer buys no fill, only latency); a deadline flush
    below ``target_fill`` grows it (the arrival rate needs a longer window
    to amortize).  Flushes between those regimes — and lifecycle flushes at
    close — leave the window unchanged.  All timing flows through the
    injectable ``clock``, so every decision replays deterministically.

    >>> ctl = DelayController(1.0, 8.0, clock=ManualClock())
    >>> ctl.delay_ms                                     # starts patient
    8.0
    >>> ctl.observe_flush(n_requests=8, capacity=8, deadline_hit=False)
    >>> ctl.delay_ms                                     # filled early: shrink
    4.0
    >>> ctl.observe_flush(n_requests=1, capacity=8, deadline_hit=True)
    >>> ctl.delay_ms                                     # flushed sparse: grow
    6.0
    """

    def __init__(
        self,
        min_delay_ms: float,
        max_delay_ms: float,
        *,
        clock: Callable[[], float] = time.monotonic,
        shrink: float = 0.5,
        grow: float = 1.5,
        target_fill: float = 0.5,
        rate_window: int = 256,
    ):
        if not 0 < min_delay_ms <= max_delay_ms:
            raise ValueError("need 0 < min_delay_ms <= max_delay_ms")
        if not 0 < shrink < 1 < grow:
            raise ValueError("need 0 < shrink < 1 < grow")
        if not 0 < target_fill <= 1:
            raise ValueError("need 0 < target_fill <= 1")
        self.min_delay_s = min_delay_ms / 1e3
        self.max_delay_s = max_delay_ms / 1e3
        self.shrink = shrink
        self.grow = grow
        self.target_fill = target_fill
        self._clock = clock
        self._delay_s = self.max_delay_s  # start patient: latency floor is
        self._arrivals: deque = deque(maxlen=rate_window)  # opt-in, fill is not
        self._lock = threading.Lock()
        self.shrinks = 0
        self.grows = 0

    @property
    def delay_s(self) -> float:
        return self._delay_s

    @property
    def delay_ms(self) -> float:
        return self._delay_s * 1e3

    def note_arrival(self) -> None:
        """Record one request arrival (timestamped on the injected clock)."""
        with self._lock:
            self._arrivals.append(self._clock())

    def arrival_rate(self) -> float:
        """Requests/second over the rolling arrival window (0.0 until two
        arrivals at distinct clock readings)."""
        with self._lock:
            if len(self._arrivals) < 2:
                return 0.0
            span = self._arrivals[-1] - self._arrivals[0]
            return (len(self._arrivals) - 1) / span if span > 0 else 0.0

    def observe_flush(
        self, *, n_requests: int, capacity: int, deadline_hit: bool
    ) -> None:
        """Adapt to one flushed batch: shrink on an early full batch, grow on
        a sparse deadline flush, hold otherwise."""
        with self._lock:
            if not deadline_hit and n_requests >= capacity:
                self._delay_s = max(self.min_delay_s, self._delay_s * self.shrink)
                self.shrinks += 1
            elif deadline_hit and n_requests < self.target_fill * capacity:
                self._delay_s = min(self.max_delay_s, self._delay_s * self.grow)
                self.grows += 1
