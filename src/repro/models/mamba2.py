"""Mamba-2 (SSD, arXiv:2405.21060) layer: chunked train scan + O(1) decode.

The SSD chunked algorithm is TPU-native by construction: within a chunk the
recurrence is a (Q×Q) masked matmul (MXU work), across chunks a short
``lax.scan`` carries the (nh, ds, hp) state. All state math runs in fp32.

  h_t = exp(a_t) * h_{t-1} + B_t (dt_t x_t),   a_t = -exp(A_log) * dt_t
  y_t = C_t · h_t + D_skip * x_t
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import Params, linear, linear_init, rmsnorm, rmsnorm_init


class MambaConfig(NamedTuple):
    d_model: int
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    n_groups: int = 1
    conv_kernel: int = 4
    chunk: int = 256

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state


def mamba_init(key, cfg: MambaConfig, dtype) -> Params:
    ks = jax.random.split(key, 5)
    di, nh = cfg.d_inner, cfg.n_heads
    proj_out = 2 * di + 2 * cfg.n_groups * cfg.d_state + nh
    return {
        "in_proj": linear_init(ks[0], cfg.d_model, proj_out, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_kernel, cfg.conv_dim)) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((cfg.conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((nh,), 0.01))).astype(jnp.float32),
        "norm": rmsnorm_init(di, dtype),
        "out_proj": linear_init(ks[2], di, cfg.d_model, dtype),
    }


def _split_proj(cfg: MambaConfig, zxbcdt: jax.Array):
    di, gs, nh = cfg.d_inner, cfg.n_groups * cfg.d_state, cfg.n_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : 2 * di + 2 * gs]
    dt = zxbcdt[..., 2 * di + 2 * gs :]
    return z, xbc, dt


def _causal_conv(p: Params, cfg: MambaConfig, xbc: jax.Array) -> jax.Array:
    """Depthwise causal conv over the sequence (train/prefill path)."""
    k = cfg.conv_kernel
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    w = p["conv_w"].astype(xbc.dtype)
    out = sum(pad[:, i : i + xbc.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + p["conv_b"].astype(xbc.dtype))


def _ssd_chunked(cfg: MambaConfig, x, dt, B_, C_, A, h0=None, constrain=None):
    """x (B,S,nh,hp); dt (B,S,nh); B_,C_ (B,S,ng,ds); A (nh,) negative.

    Returns (y (B,S,nh,hp), h_final (B,nh,ds,hp)). fp32 math.
    """
    pin = constrain or (lambda t, *a: t)
    Bb, S, nh, hp = x.shape
    ng, ds = B_.shape[2], B_.shape[3]
    Q = min(cfg.chunk, S)
    while S % Q:  # largest divisor of S not exceeding the chunk size
        Q -= 1
    nc = S // Q
    rep = nh // ng

    xf = (x * dt[..., None]).astype(jnp.float32)            # dt-scaled input
    a = (dt.astype(jnp.float32) * A)                        # (B,S,nh), <= 0
    Bg = jnp.repeat(B_.astype(jnp.float32), rep, axis=2)    # (B,S,nh,ds)
    Cg = jnp.repeat(C_.astype(jnp.float32), rep, axis=2)

    def chunked(t):
        return t.reshape((Bb, nc, Q) + t.shape[2:])

    xc, ac, Bc, Cc = map(chunked, (xf, a, Bg, Cg))
    cum = jnp.cumsum(ac, axis=2)                            # (B,nc,Q,nh)
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]     # (B,nc,Q,Q,nh) i,j
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)

    # intra-chunk: y[i] = sum_j (C_i·B_j) L[i,j] x[j]
    cb = jnp.einsum("bnihd,bnjhd->bnijh", Cc, Bc)           # (B,nc,Q,Q,nh)
    y_intra = jnp.einsum("bnijh,bnijh,bnjhp->bnihp", cb, L, xc)

    # chunk states: S_n = sum_j exp(cum_last - cum_j) B_j ⊗ x_j
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)            # (B,nc,Q,nh)
    S_n = jnp.einsum("bnjh,bnjhd,bnjhp->bnhdp", decay_end, Bc, xc)

    # inter-chunk recurrence over n: h_{n+1} = h_n * exp(cum_last_n) + S_n
    chunk_decay = jnp.exp(cum[:, :, -1, :])                 # (B,nc,nh)

    def scan_body(h, inp):
        s_n, dec = inp
        h_out = h * dec[..., None, None] + s_n
        return h_out, h  # emit state *entering* the chunk

    h_init = (
        jnp.zeros((Bb, nh, ds, hp), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    )
    h_init = pin(h_init, "batch", "model", None, None)
    h_last, h_in = jax.lax.scan(
        scan_body,
        h_init,
        (jnp.moveaxis(S_n, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_in = jnp.moveaxis(h_in, 0, 1)                         # (B,nc,nh,ds,hp)

    # inter-chunk output: C_i · h_in * exp(cum_i)
    y_inter = jnp.einsum("bnihd,bnhdp->bnihp", Cc, h_in) * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(Bb, S, nh, hp)
    return y, h_last


class MambaCache(NamedTuple):
    conv: jax.Array   # (B, k-1, conv_dim) last inputs to the causal conv
    ssm: jax.Array    # (B, nh, ds, hp) fp32 state


def init_mamba_cache(cfg: MambaConfig, batch: int, dtype) -> MambaCache:
    return MambaCache(
        conv=jnp.zeros((batch, cfg.conv_kernel - 1, cfg.conv_dim), dtype),
        ssm=jnp.zeros((batch, cfg.n_heads, cfg.d_state, cfg.head_dim), jnp.float32),
    )


def mamba_train(p: Params, cfg: MambaConfig, x: jax.Array, constrain=None) -> jax.Array:
    """Full-sequence forward (train / prefill). x (B,S,D) -> (B,S,D).

    ``constrain(x, *axes)`` pins activation shardings (batch on dim0, heads /
    channels on the model axis) — without the anchors SPMD's rematted backward
    picks a conflicting layout and replicates the 33k-wide in_proj output
    (32 GiB/device on jamba; refuted hypothesis H-ssd, EXPERIMENTS §Perf).
    """
    pin = constrain or (lambda t, *a: t)
    B, S, D = x.shape
    nh, hp, ds, ng = cfg.n_heads, cfg.head_dim, cfg.d_state, cfg.n_groups
    zxbcdt = pin(linear(p["in_proj"], x), "batch", None, "model")
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    xbc = pin(_causal_conv(p, cfg, xbc), "batch", None, "model")
    xs = pin(
        xbc[..., : cfg.d_inner].reshape(B, S, nh, hp), "batch", None, "model", None
    )
    B_ = xbc[..., cfg.d_inner : cfg.d_inner + ng * ds].reshape(B, S, ng, ds)
    C_ = xbc[..., cfg.d_inner + ng * ds :].reshape(B, S, ng, ds)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, _ = _ssd_chunked(cfg, xs, dt, B_, C_, A, constrain=constrain)
    y = y + (p["D_skip"][:, None] * xs.astype(jnp.float32))
    y = pin(y.reshape(B, S, cfg.d_inner).astype(x.dtype), "batch", None, "model")
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    return linear(p["out_proj"], y)


def mamba_decode(p: Params, cfg: MambaConfig, x: jax.Array, cache: MambaCache):
    """One-token step. x (B,1,D) -> (y (B,1,D), new_cache). O(1) in context."""
    B = x.shape[0]
    nh, hp, ds, ng = cfg.n_heads, cfg.head_dim, cfg.d_state, cfg.n_groups
    z, xbc, dt = _split_proj(cfg, linear(p["in_proj"], x))
    xbc = xbc[:, 0]                                          # (B, conv_dim)
    # conv ring: window = [cache.conv, xbc]
    window = jnp.concatenate([cache.conv, xbc[:, None]], axis=1)  # (B,k,conv)
    w = p["conv_w"].astype(xbc.dtype)
    conv_out = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", window, w) + p["conv_b"].astype(xbc.dtype)
    )
    new_conv = window[:, 1:]
    xs = conv_out[..., : cfg.d_inner].reshape(B, nh, hp)
    B_ = conv_out[..., cfg.d_inner : cfg.d_inner + ng * ds].reshape(B, ng, ds)
    C_ = conv_out[..., cfg.d_inner + ng * ds :].reshape(B, ng, ds)
    rep = nh // ng
    Bg = jnp.repeat(B_.astype(jnp.float32), rep, axis=1)     # (B,nh,ds)
    Cg = jnp.repeat(C_.astype(jnp.float32), rep, axis=1)
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,nh)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dtv * A)                                 # (B,nh)
    xdt = xs.astype(jnp.float32) * dtv[..., None]            # (B,nh,hp)
    h = cache.ssm * decay[..., None, None] + jnp.einsum("bhd,bhp->bhdp", Bg, xdt)
    y = jnp.einsum("bhd,bhdp->bhp", Cg, h) + p["D_skip"][:, None] * xs.astype(jnp.float32)
    y = y.reshape(B, 1, cfg.d_inner).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    return linear(p["out_proj"], y), MambaCache(new_conv, h)
