"""Common model layers (pure-functional JAX, param pytrees are plain dicts)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

Params = dict


# ---------------------------------------------------------------- RMSNorm ---
def rmsnorm_init(dim: int, dtype) -> Params:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(p: Params, x: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    """Variance reduction in f32; the normalize/scale multiplies stay in the
    residual dtype. Upcasting the whole activation turns every TP-boundary
    collective (fwd partials + bwd cotangents) f32 — 2x the wire bytes
    (confirmed hypothesis H-bf16-ar, EXPERIMENTS §Perf iteration 1)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * p["scale"].astype(x.dtype)


# ------------------------------------------------------------------- RoPE ---
def rope_angles(positions: jax.Array, head_dim: int, theta: float) -> tuple:
    """positions (...,) -> cos/sin tables (..., head_dim/2)."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., S, H, hd); cos/sin (..., S, hd/2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]  # add head axis
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


# ----------------------------------------------------------------- Linear ---
def linear_init(key, d_in: int, d_out: int, dtype, *, bias: bool = False) -> Params:
    scale = d_in ** -0.5
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def linear(p: Params, x: jax.Array) -> jax.Array:
    out = x @ p["w"].astype(x.dtype)
    if "b" in p:
        out = out + p["b"].astype(x.dtype)
    return out


# -------------------------------------------------------------------- MLP ---
def mlp_init(key, d_model: int, d_ff: int, dtype, *, gated: bool = True) -> Params:
    ks = jax.random.split(key, 3)
    p = {
        "w_in": linear_init(ks[0], d_model, d_ff, dtype),
        "w_out": linear_init(ks[1], d_ff, d_model, dtype),
    }
    if gated:
        p["w_gate"] = linear_init(ks[2], d_model, d_ff, dtype)
    return p


def mlp(p: Params, x: jax.Array) -> jax.Array:
    h = linear(p["w_in"], x)
    if "w_gate" in p:
        h = jax.nn.silu(linear(p["w_gate"], x)) * h  # SwiGLU
    else:
        h = jax.nn.gelu(h)
    return linear(p["w_out"], h)


# -------------------------------------------------------------- Embedding ---
def embed_init(key, vocab: int, d_model: int, dtype) -> Params:
    return {"table": (jax.random.normal(key, (vocab, d_model), jnp.float32)).astype(dtype)}


def embed(p: Params, tokens: jax.Array, compute_dtype) -> jax.Array:
    return p["table"].astype(compute_dtype)[tokens]


def unembed(p: Params, x: jax.Array, vocab_size: Optional[int] = None) -> jax.Array:
    """Tied logits head: x (..., D) @ table.T -> (..., V_pad) in fp32.

    Rows past ``vocab_size`` are EP-padding (vocab-parallel table) and get
    -inf logits so sampling/CE never selects them.
    """
    logits = x.astype(jnp.float32) @ p["table"].astype(jnp.float32).T
    v_pad = p["table"].shape[0]
    if vocab_size is not None and v_pad != vocab_size:
        logits = jnp.where(jnp.arange(v_pad) < vocab_size, logits, -jnp.inf)
    return logits
