"""Mixture-of-Experts layer with sort-based dispatch — the paper's model D as
a first-class framework feature.

Token routing *is* the paper's cluster sort (DESIGN.md §3): the expert id is
the key's "most significant digit", expert-parallel shards are the cluster
nodes, and dispatch is one MSD-radix ``all_to_all`` each way with **zero**
inter-shard merging — the exact property the paper built model D for. The
stable grouping sort inside ``partition_exchange`` preserves arrival order per
expert (the paper's stability argument, doing real work here).

Everything slab-shaped comes from ``repro.exchange`` (the unified adaptive
exchange layer): ``partition_exchange``/``combine_exchange`` are the wire,
``expert_capacity`` is the one capacity formula (shared rounding with the
sort path's ``slab_geometry``), and ``moe_apply_adaptive`` closes the same
capacity-learning loop model-D sort has — per-(n_experts, top_k, token
bucket) expert capacity factors learned from observed telemetry and
persisted in the plan cache, so a skewed routing distribution pays its
overflow/drop penalty once per deployment, zero after restart.

Layout: experts are sharded over the ``model`` mesh axis; tokens entering the
layer are sharded over ``(pod, data, model)`` (the reshard is a free view
change for XLA). Fixed per-(sender, expert) capacity with overflow-drop
follows GShard/Switch semantics; ``capacity_factor`` controls it, the train
loop monitors the overflow signal (fault_tolerance.py treats routing collapse
as an anomaly), and the aux load-balancing loss keeps the router near-uniform.
"""
from __future__ import annotations

import math
from functools import lru_cache
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.exchange import (
    combine_exchange,
    expert_capacity,
    partition_exchange,
    run_with_capacity_retries,
)
from .layers import Params, linear_init

DEFAULT_CAPACITY_FACTOR = 2.0


class MoEConfig(NamedTuple):
    d_model: int
    d_ff: int                 # per-expert hidden dim
    n_experts: int
    top_k: int
    capacity_factor: float = DEFAULT_CAPACITY_FACTOR
    mlp_gated: bool = True
    compress_dispatch: bool = False   # int8 a2a payloads (beyond paper)


def moe_init(key, cfg: MoEConfig, dtype, *, ep_shards: int) -> Params:
    """Expert weights stacked (E_pad, ...); E padded to a multiple of ep_shards
    with dummy experts the router can never select (logits masked)."""
    e_pad = math.ceil(cfg.n_experts / ep_shards) * ep_shards
    ks = jax.random.split(key, 4)
    s_in = cfg.d_model ** -0.5
    s_out = cfg.d_ff ** -0.5
    p = {
        "router": linear_init(ks[0], cfg.d_model, e_pad, jnp.float32),
        "w_in": (jax.random.normal(ks[1], (e_pad, cfg.d_model, cfg.d_ff)) * s_in).astype(dtype),
        "w_out": (jax.random.normal(ks[2], (e_pad, cfg.d_ff, cfg.d_model)) * s_out).astype(dtype),
    }
    if cfg.mlp_gated:
        p["w_gate"] = (
            jax.random.normal(ks[3], (e_pad, cfg.d_model, cfg.d_ff)) * s_in
        ).astype(dtype)
    return p


def router_probs(p: Params, cfg: MoEConfig, x: jax.Array):
    """x (T, D) -> (probs (T, E_pad), top_idx (T, k), top_gate (T, k), aux_loss)."""
    e_pad = p["router"]["w"].shape[-1]
    logits = (x.astype(jnp.float32) @ p["router"]["w"]).astype(jnp.float32)
    if e_pad != cfg.n_experts:  # mask dummy padding experts
        pad_mask = jnp.arange(e_pad) >= cfg.n_experts
        logits = jnp.where(pad_mask, -jnp.inf, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    top_gate, top_idx = jax.lax.top_k(probs, cfg.top_k)
    top_gate = top_gate / jnp.maximum(top_gate.sum(-1, keepdims=True), 1e-9)
    # Switch-style aux loss: E * sum_e f_e * P_e  (f = token fraction, P = prob mass)
    f = jnp.zeros((e_pad,), jnp.float32).at[top_idx.reshape(-1)].add(1.0)
    f = f / jnp.maximum(f.sum(), 1.0)
    # sum/max(T,1), not mean: the mean of an empty axis is NaN, which would
    # poison the aux loss (and every grad) for a drained shard/microbatch
    P_mass = probs.sum(axis=0) / max(x.shape[0], 1)
    aux = cfg.n_experts * jnp.sum(f * P_mass)
    return probs, top_idx, top_gate, aux


def collapse_router(p: Params, logit_scale: float = 10.0) -> Params:
    """A copy of ``p`` whose router concentrates routing on a few low-index
    experts — the worst-case skew benchmarks/demos/tests use to exercise the
    capacity-learning loop.

    The single nonzero router column gives expert 0 logit
    ``logit_scale * sum(x)`` while every other real expert sits at exactly
    0: tokens with positive ``sum(x)`` route to expert 0, the rest tie at 0
    and drain to the lowest-index remaining experts (``top_k`` ties break
    low), so a handful of experts absorb the whole batch regardless of the
    token distribution.
    """
    w = p["router"]["w"]
    # index the expert axis from the end: the router weight is (D, E_pad)
    # standalone but (n_groups, D, E_pad) inside stacked train params
    return {**p, "router": {"w": jnp.zeros_like(w).at[..., 0].set(logit_scale)}}


def moe_apply_local(
    p: Params,
    cfg: MoEConfig,
    x: jax.Array,
    axis_name: str,
    all_axes: tuple = (),
    *,
    capacity: Optional[int] = None,
    with_stats: bool = False,
):
    """shard_map body. x: (T_loc, D) local token slice; expert weights already
    sliced to (E_loc, ...) by shard_map in_specs. Returns (y (T_loc, D), aux,
    overflow) with aux/overflow replicated over ``all_axes``.

    ``capacity`` overrides the per-(sender, expert) token capacity (default:
    ``expert_capacity`` from ``cfg.capacity_factor`` — the shared exchange-
    layer formula).  ``with_stats=True`` returns
    ``(y, aux, dropped, counts, peak, overflow)`` instead: ``counts`` are
    EP-group-global per-expert token counts, ``peak`` the max per-(sender,
    expert) count, ``dropped`` the EP-group total of overflow-dropped tokens
    — the exchange-telemetry signal ``moe_apply_adaptive`` reports into the
    capacity-learning loop.
    """
    T, D = x.shape
    ep = jax.lax.axis_size(axis_name)
    e_loc = p["w_in"].shape[0]          # local experts (already sharded)
    e_pad = e_loc * ep

    # --- routing (router weights replicated) ---
    probs, top_idx, top_gate, aux = router_probs(p, cfg, x)

    # --- dispatch = paper model D: one-step MSD-radix all_to_all ---
    keys = top_idx.reshape(-1).astype(jnp.int32)            # (T*k,) expert ids
    vals = jnp.repeat(x, cfg.top_k, axis=0)                 # (T*k, D)
    cap = capacity if capacity is not None else expert_capacity(
        T, cfg.top_k, cfg.n_experts, cfg.capacity_factor
    )
    ex = partition_exchange(
        keys, vals, keys, axis_name, capacity=cap, n_buckets=e_pad,
        compress=cfg.compress_dispatch,
    )
    # recv: (ep, e_loc*cap, D) -> (e_loc, ep*cap, D) grouped per local expert
    recv = ex.recv_values.reshape(ep, e_loc, cap, D).transpose(1, 0, 2, 3)
    recv = recv.reshape(e_loc, ep * cap, D)
    rmask = (ex.recv_src_slot.reshape(ep, e_loc, cap) >= 0).transpose(1, 0, 2)
    rmask = rmask.reshape(e_loc, ep * cap)

    # --- local expert FFN (the per-node OpenMP work of Fig 4) ---
    h = jnp.einsum("etd,edf->etf", recv, p["w_in"].astype(recv.dtype))
    if "w_gate" in p:
        g = jnp.einsum("etd,edf->etf", recv, p["w_gate"].astype(recv.dtype))
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    y = jnp.einsum("etf,efd->etd", h, p["w_out"].astype(recv.dtype))
    y = jnp.where(rmask[..., None], y, 0.0)

    # --- combine = inverse exchange, then gate-weighted sum over k replicas ---
    y = y.reshape(e_loc, ep, cap, D).transpose(1, 0, 2, 3).reshape(ep, e_loc * cap, D)
    back = combine_exchange(y, ex, axis_name)               # (T*k, D)
    back = back.reshape(T, cfg.top_k, D)
    out = jnp.einsum("tkd,tk->td", back.astype(jnp.float32), top_gate)
    overflow = ex.overflow
    if all_axes:
        aux = jax.lax.pmean(aux, all_axes)
        rest = tuple(a for a in all_axes if a != axis_name)
        if rest:  # overflow is already pmax'd over the EP axis
            overflow = jax.lax.pmax(overflow, rest)
    out = out.astype(x.dtype)
    if with_stats:
        counts = jax.lax.psum(ex.counts, axis_name)         # (e_pad,) global
        dropped = jax.lax.psum(
            jnp.sum(jnp.maximum(ex.counts - cap, 0)), axis_name
        )
        peak = jax.lax.pmax(jnp.max(ex.counts), axis_name)
        return out, aux, dropped, counts, peak, overflow
    return out, aux, overflow


def moe_apply_ep_replicated(
    p: Params,
    cfg: MoEConfig,
    x: jax.Array,
    ep_axis: Optional[str] = None,
    all_axes: tuple = (),
    *,
    capacity: Optional[int] = None,
    with_stats: bool = False,
):
    """MoE forward with tokens *replicated* over the EP axis (decode path, and
    the single-device fallback when ``ep_axis is None``).

    Each EP shard routes the same tokens but computes only its local experts,
    then contributions are psum'd over the EP axis. No all_to_all: for tiny
    decode batches the duplicate routing FLOPs are cheaper than the collective
    latency (hypothesis H-serve in EXPERIMENTS.md §Perf).

    ``capacity`` / ``with_stats`` follow ``moe_apply_local``'s contract:
    ``with_stats=True`` returns ``(y, aux, dropped, counts, peak, overflow)``
    with per-expert token ``counts``, the max per-expert ``peak``, and the
    ``dropped`` token total — what ``moe_apply_adaptive`` feeds the shared
    exchange telemetry.
    """
    T, D = x.shape
    ep = 1 if ep_axis is None else jax.lax.axis_size(ep_axis)
    my = 0 if ep_axis is None else jax.lax.axis_index(ep_axis)
    e_loc = p["w_in"].shape[0]

    probs, top_idx, top_gate, aux = router_probs(p, cfg, x)

    keys = top_idx.reshape(-1).astype(jnp.int32)             # (T*k,) global ids
    local = keys - my * e_loc
    mine = (local >= 0) & (local < e_loc)
    bucket = jnp.where(mine, local, e_loc)                   # trash bucket e_loc
    cap = capacity if capacity is not None else expert_capacity(
        T, cfg.top_k, cfg.n_experts, cfg.capacity_factor
    )

    order = jnp.argsort(bucket, stable=True)
    sorted_b = bucket[order]
    counts = jnp.bincount(bucket, length=e_loc + 1).astype(jnp.int32)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(keys.shape[0], dtype=jnp.int32) - offsets[sorted_b]
    valid = (pos < cap) & (sorted_b < e_loc)
    slot_sorted = jnp.where(valid, sorted_b * cap + pos, e_loc * cap)

    vals = jnp.repeat(x, cfg.top_k, axis=0)                  # (T*k, D)
    slab = jnp.zeros((e_loc * cap, D), x.dtype).at[slot_sorted].set(
        vals[order], mode="drop"
    )
    smask = jnp.zeros((e_loc * cap,), bool).at[slot_sorted].set(True, mode="drop")
    send_slot = (
        jnp.full((keys.shape[0],), -1, jnp.int32)
        .at[order]
        .set(jnp.where(valid, slot_sorted, -1).astype(jnp.int32))
    )

    recv = slab.reshape(e_loc, cap, D)
    h = jnp.einsum("etd,edf->etf", recv, p["w_in"].astype(recv.dtype))
    if "w_gate" in p:
        g = jnp.einsum("etd,edf->etf", recv, p["w_gate"].astype(recv.dtype))
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    y = jnp.einsum("etf,efd->etd", h, p["w_out"].astype(recv.dtype))
    y = jnp.where(smask.reshape(e_loc, cap)[..., None], y, 0.0)

    flat = y.reshape(e_loc * cap, D)
    safe = jnp.clip(send_slot, 0, flat.shape[0] - 1)
    back = jnp.where((send_slot >= 0)[:, None], flat[safe], 0.0)
    back = back.reshape(T, cfg.top_k, D)
    out = jnp.einsum("tkd,tk->td", back.astype(jnp.float32), top_gate)
    counts_real = counts[:e_loc]
    overflow = jnp.max(counts_real) > cap
    if ep_axis is not None:
        out = jax.lax.psum(out, ep_axis)
        overflow = jax.lax.pmax(overflow, ep_axis)
    if all_axes:
        aux = jax.lax.pmean(aux, all_axes)
        rest = tuple(a for a in all_axes if a != ep_axis)
        if rest:
            overflow = jax.lax.pmax(overflow, rest)
    out = out.astype(x.dtype)
    if with_stats:
        # inside the branch so the plain (decode) forward never issues the
        # extra collectives, jit or eager
        dropped = jnp.sum(jnp.maximum(counts_real - cap, 0))
        peak = jnp.max(counts_real)
        if ep_axis is not None:
            counts_real = jax.lax.all_gather(counts_real, ep_axis).reshape(-1)
            dropped = jax.lax.psum(dropped, ep_axis)
            peak = jax.lax.pmax(peak, ep_axis)
        return out, aux, dropped, counts_real, peak, overflow
    return out, aux, overflow


# ------------------------------------------------------- adaptive dispatch ---
def moe_plan_key(tokens: int, cfg: MoEConfig, dtype=jnp.float32, mesh=None) -> str:
    """Plan-cache cell for MoE expert-capacity learning.

    Keyed per (n_experts, top_k, pow2 token bucket, dtype, mesh fingerprint)
    — the quantities ``expert_capacity`` depends on — so skew learned for one
    routing shape never bleeds into another.  Lives in the same ``learned``
    table as the sort cells (docs/plan-cache.md).
    """
    from repro.core.bitonic import next_pow2
    from repro.engine.planner import mesh_fingerprint

    return (
        f"moe/E{cfg.n_experts}k{cfg.top_k}|{next_pow2(tokens)}"
        f"|{jnp.dtype(dtype).name}|{mesh_fingerprint(mesh)}"
    )


@lru_cache(maxsize=256)
def _compiled_moe_replicated(cfg: MoEConfig, capacity: int):
    """One jitted single-host forward per (config, capacity) — the factory
    ``run_with_capacity_retries`` counts retry-forced fresh compiles on."""

    def f(p, x):
        return moe_apply_ep_replicated(p, cfg, x, capacity=capacity, with_stats=True)

    return jax.jit(f)


def _drop_report(telemetry, attempt_drops: list):
    """Wrap a telemetry callback with served/averted drop accounting.

    The retry driver reports once, after the final attempt; routing (and so
    per-attempt drops) is identical across attempts, only the capacity
    moves — the final attempt's drops reached the served output iff it
    still overflowed (peak > its capacity), every earlier attempt's were
    recomputed away by the retry.  Shared by both adaptive MoE paths
    (replicated and shard_map expert-parallel) so the telemetry schema
    can't drift between them.
    """
    if telemetry is None:
        return None

    def report(**kwargs):
        served = (
            attempt_drops[-1]
            if attempt_drops and kwargs["peak"] > kwargs["capacity"]
            else 0
        )
        # later attempts re-drop a subset of the first attempt's tokens,
        # so distinct at-risk tokens = the first (largest) attempt's
        # count, not the sum across attempts
        averted = max(attempt_drops, default=0) - served
        telemetry(dropped=served, dropped_averted=averted, **kwargs)

    return report


def moe_apply_adaptive(
    p: Params,
    cfg: MoEConfig,
    x: jax.Array,
    *,
    planner=None,
    capacity_factor: Optional[float] = None,
    telemetry=None,
    max_retries: int = 4,
):
    """Adaptive single-host MoE forward: learned capacity, retry over drop.

    The MoE twin of the adaptive ``cluster_sort`` path.  Runs
    ``moe_apply_ep_replicated`` at the learned expert capacity factor for
    this (n_experts, top_k, token bucket) cell, retries with doubled
    capacity when the router's skew overflows it (``capacity == T * top_k``
    is the loss-free bound, so retries always converge), and reports the
    call's exchange telemetry — peak per-expert token count, overflow/
    retry/recompile events, and drop counts (``dropped`` = tokens the served
    output actually lost, ``dropped_averted`` = tokens retried attempts
    would have lost) — through the planner, which folds it into a persisted
    capacity factor:
    a skewed routing distribution pays its overflow penalty once per
    deployment, zero after restart.  When retries are exhausted the last
    attempt's output is returned with its drops intact (GShard semantics)
    rather than raising — serving must degrade, not die.

    By default the loop runs through ``planner`` (the process-wide default
    planner when None); passing an explicit ``capacity_factor=`` or
    ``telemetry=`` opts the call out of the whole loop, reading and
    writing, exactly like the sort paths.

    Returns ``(y, aux, counts)`` with per-expert token ``counts`` — the
    final attempt never overflowed unless retries were exhausted, so unlike
    the fixed path there is no overflow flag to thread through.
    """
    T, _ = x.shape
    m = T * cfg.top_k
    if capacity_factor is None and telemetry is None:
        from repro.engine.planner import default_planner

        planner = planner or default_planner()
        key = moe_plan_key(T, cfg, x.dtype)
        capacity_factor = planner.capacity_factor_for(
            key, default=cfg.capacity_factor
        )
        telemetry = planner.exchange_recorder(key, default=cfg.capacity_factor)
    elif capacity_factor is None:
        capacity_factor = cfg.capacity_factor
    cap = expert_capacity(T, cfg.top_k, cfg.n_experts, capacity_factor)
    # cfg.capacity_factor is dead inside the compiled forward (capacity is
    # explicit), so normalize it out of the compile-cache key: two defaults
    # over the same architecture share one executable per capacity
    ccfg = cfg._replace(capacity_factor=0.0)

    attempt_drops = []

    def run_fn(fn):
        out, aux, dropped, counts, peak, overflow = fn(p, x)
        attempt_drops.append(int(dropped))
        return out, aux, counts, peak, overflow

    report = _drop_report(telemetry, attempt_drops)

    (y, aux), counts = run_with_capacity_retries(
        lambda c: _compiled_moe_replicated(ccfg, c),
        run_fn,
        m=m,
        part_buckets=max(cfg.n_experts, 1),
        cap=cap,
        max_retries=max_retries,
        telemetry=report,
        lru=_compiled_moe_replicated,
        label="moe_apply_adaptive",
        strict=False,
    )
    return y, aux, counts


@lru_cache(maxsize=256)
def _compiled_moe_local(cfg: MoEConfig, capacity: int, mesh, axes: tuple, ep_axis: str):
    """One jitted shard_map expert-parallel forward per (config, capacity,
    mesh, axes) — the factory ``run_with_capacity_retries`` counts
    retry-forced fresh compiles on.  ``jax.Mesh`` hashes by (devices,
    axis names), so two calls over the same topology share one executable
    per capacity, exactly like the replicated twin.

    ``dropped``/``counts``/``peak`` come out *mesh*-global (the
    ``moe_apply_local`` stats are EP-group-global; the extra psum/pmax here
    folds in the non-EP axes), so the host-side capacity loop reads one
    scalar per step regardless of topology.
    """

    def body(mp, xt):
        out, aux, dropped, counts, peak, overflow = moe_apply_local(
            mp, cfg, xt, ep_axis, axes, capacity=capacity, with_stats=True
        )
        rest = tuple(a for a in axes if a != ep_axis)
        if rest:
            dropped = jax.lax.psum(dropped, rest)
            counts = jax.lax.psum(counts, rest)
            peak = jax.lax.pmax(peak, rest)
        return out, aux, dropped, counts, peak, overflow

    def f(p, x):
        (p_spec, x_spec), out_specs = moe_shard_specs(
            p, mesh_axes=axes, ep_axis=ep_axis, with_stats=True
        )
        return jax.shard_map(
            body,
            mesh=mesh,
            in_specs=(p_spec, x_spec),
            out_specs=out_specs,
            check_vma=False,
        )(p, x)

    return jax.jit(f)


def moe_apply_local_adaptive(
    p: Params,
    cfg: MoEConfig,
    x: jax.Array,
    mesh,
    *,
    axes: tuple = ("data", "model"),
    ep_axis: str = "model",
    planner=None,
    capacity_factor: Optional[float] = None,
    telemetry=None,
    max_retries: int = 4,
):
    """Adaptive *expert-parallel* MoE forward: the shard_map all_to_all
    dispatch (``moe_apply_local``) under the shared capacity-retry driver.

    The mesh twin of ``moe_apply_adaptive``: runs the paper's model-D
    dispatch at the learned expert capacity factor for this (n_experts,
    top_k, token bucket, *mesh*) cell, retries with doubled capacity when
    the router's skew overflows it, and reports the call's exchange
    telemetry through the planner so the factor persists in the plan cache
    — training and serving processes that share a topology (and a
    ``$REPRO_SORT_PLANS`` file) warm each other.  Capacity is a static
    compile-cache key, so a learned bump recompiles exactly once; when
    retries are exhausted the last attempt's output is returned with its
    drops intact (GShard semantics).

    ``x`` is the *global* (T, D) token batch; T must divide the mesh (the
    shard_map in_specs split it over every axis in ``axes``).  Passing an
    explicit ``capacity_factor=`` or ``telemetry=`` opts out of the
    planner loop, exactly like the replicated path.

    Returns ``(y, aux, counts)`` with mesh-global per-expert ``counts``.
    """
    T, _ = x.shape
    n_dev = 1
    for a in axes:
        n_dev *= mesh.shape[a]
    if T % n_dev:
        raise ValueError(f"tokens {T} must divide the {n_dev}-device mesh")
    t_loc = T // n_dev                     # per-sender token slice
    m = t_loc * cfg.top_k                  # per-sender assignments
    if capacity_factor is None and telemetry is None:
        from repro.engine.planner import default_planner

        planner = planner or default_planner()
        key = moe_plan_key(T, cfg, x.dtype, mesh)
        capacity_factor = planner.capacity_factor_for(
            key, default=cfg.capacity_factor
        )
        telemetry = planner.exchange_recorder(key, default=cfg.capacity_factor)
    elif capacity_factor is None:
        capacity_factor = cfg.capacity_factor
    cap = expert_capacity(t_loc, cfg.top_k, cfg.n_experts, capacity_factor)
    ccfg = cfg._replace(capacity_factor=0.0)

    attempt_drops = []

    def run_fn(fn):
        out, aux, dropped, counts, peak, overflow = fn(p, x)
        attempt_drops.append(int(dropped))
        return out, aux, counts, peak, overflow

    report = _drop_report(telemetry, attempt_drops)

    (y, aux), counts = run_with_capacity_retries(
        lambda c: _compiled_moe_local(ccfg, c, mesh, tuple(axes), ep_axis),
        run_fn,
        m=m,
        part_buckets=max(cfg.n_experts, 1),
        cap=cap,
        max_retries=max_retries,
        telemetry=report,
        lru=_compiled_moe_local,
        label="moe_apply_local_adaptive",
        strict=False,
    )
    return y, aux, counts


def moe_shard_specs(
    params: Params,
    mesh_axes=("pod", "data", "model"),
    ep_axis="model",
    *,
    with_stats: bool = False,
):
    """PartitionSpecs for calling moe_apply_local under shard_map.

    Tokens shard over every mesh axis; experts over the EP axis; router
    replicated. Returns (in_specs for (params, x), out_specs) — the
    out_specs match ``moe_apply_local``'s 3-tuple, or its 6-tuple stats
    contract when ``with_stats`` (aux/dropped/counts/peak/overflow all
    replicated).
    """
    from jax.sharding import PartitionSpec as P

    def leaf_spec(path):
        return P() if path[0] == "router" else P(ep_axis)

    p_spec = jax.tree_util.tree_map_with_path(
        lambda kp, _: leaf_spec(tuple(k.key for k in kp)), params
    )
    x_spec = P(tuple(mesh_axes))
    n_out = 6 if with_stats else 3
    out_specs = (P(tuple(mesh_axes)),) + (P(),) * (n_out - 1)
    return (p_spec, x_spec), out_specs
