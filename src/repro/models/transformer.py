"""Config-driven decoder stack: dense / MoE / SSM / hybrid, one code path.

The layer stack is a ``lax.scan`` over *pattern groups*: ``cfg.pattern`` is a
period (e.g. ``("attn_g",)*5 + ("attn_l",)`` for gemma3, ``("attn",) +
("mamba",)*7`` for jamba) and parameters are stacked with a leading
``n_layers/len(pattern)`` group axis. Scan keeps the HLO O(1) in depth — that
is what makes 512-way SPMD compiles of 72-layer/398B configs tractable
(DESIGN.md §5) — and ``jax.checkpoint`` around the group body gives the remat
policy a natural boundary.

Block kinds:
  attn    full/global causal attention (+MoE or dense FFN)
  attn_l  sliding-window local attention
  mamba   Mamba-2 SSD (no FFN pairing unless cfg says so — Jamba pairs FFN)
Every block is pre-norm residual: x += Block(RMSNorm(x)); FFN likewise.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import (
    AttnConfig,
    KVCache,
    attention_decode,
    attention_train,
    attn_init,
    init_kv_cache,
)
from .layers import Params, embed, embed_init, mlp, mlp_init, rmsnorm, rmsnorm_init, unembed
from .mamba2 import (
    MambaCache,
    MambaConfig,
    init_mamba_cache,
    mamba_decode,
    mamba_init,
    mamba_train,
)
from .moe import (
    MoEConfig,
    moe_apply_ep_replicated,
    moe_apply_local,
    moe_init,
    moe_shard_specs,
)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # layer pattern (period); "attn" | "attn_l" | "mamba"
    pattern: Tuple[str, ...] = ("attn",)
    # which positions in the period carry an FFN ("dense" | "moe" | None)
    ffn_pattern: Tuple[Optional[str], ...] = ("dense",)
    mlp_gated: bool = True
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    rope_theta_local: float = 10_000.0
    sliding_window: int = 0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 2.0
    compress_dispatch: bool = False   # int8 MoE a2a payloads
    # SSM
    ssm_state: int = 128
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    # modality frontend stub ("none" | "vision" | "audio")
    frontend: str = "none"
    n_frontend_tokens: int = 0
    # numerics
    param_dtype: Any = jnp.bfloat16
    compute_dtype: Any = jnp.bfloat16
    kv_chunk: int = 1024
    # remat: "dots" saves dot outputs (fast, more memory); "none" recomputes
    # everything per layer group (the giants: activation stash dominates)
    remat_policy: str = "dots"
    # notes for DESIGN/EXPERIMENTS (e.g. technique applicability)
    notes: str = ""

    @property
    def n_groups(self) -> int:
        assert self.n_layers % len(self.pattern) == 0, (self.n_layers, self.pattern)
        return self.n_layers // len(self.pattern)

    def attn_cfg(self, kind: str) -> AttnConfig:
        local = kind == "attn_l"
        return AttnConfig(
            d_model=self.d_model,
            n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads,
            head_dim=self.head_dim,
            qkv_bias=self.qkv_bias,
            qk_norm=self.qk_norm,
            rope_theta=self.rope_theta_local if local else self.rope_theta,
            sliding_window=self.sliding_window if local else 0,
            kv_chunk=self.kv_chunk,
        )

    def mamba_cfg(self) -> MambaConfig:
        return MambaConfig(
            d_model=self.d_model,
            d_state=self.ssm_state,
            head_dim=self.ssm_head_dim,
            chunk=self.ssm_chunk,
        )

    def moe_cfg(self) -> MoEConfig:
        return MoEConfig(
            d_model=self.d_model,
            d_ff=self.d_ff,
            n_experts=self.n_experts,
            top_k=self.top_k,
            capacity_factor=self.capacity_factor,
            mlp_gated=self.mlp_gated,
            compress_dispatch=self.compress_dispatch,
        )

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + stacked blocks)."""
        D, F = self.d_model, self.d_ff
        per_period = 0
        for kind, ffn in zip(self.pattern, self.ffn_pattern):
            if kind.startswith("attn"):
                per_period += D * self.head_dim * (self.n_heads + 2 * self.n_kv_heads)
                per_period += self.n_heads * self.head_dim * D
            else:
                mc = self.mamba_cfg()
                per_period += D * (2 * mc.d_inner + 2 * mc.n_groups * mc.d_state + mc.n_heads)
                per_period += mc.d_inner * D + mc.conv_kernel * mc.conv_dim
            if ffn == "dense":
                per_period += D * F * (3 if self.mlp_gated else 2)
            elif ffn == "moe":
                per_period += self.n_experts * D * F * (3 if self.mlp_gated else 2)
                per_period += D * self.n_experts
        return self.vocab_size * D + per_period * self.n_groups

    def active_param_count(self) -> int:
        """Per-token active params (MoE counts top_k experts only)."""
        D, F = self.d_model, self.d_ff
        total = self.vocab_size * D
        per_period = 0
        for kind, ffn in zip(self.pattern, self.ffn_pattern):
            if kind.startswith("attn"):
                per_period += D * self.head_dim * (self.n_heads + 2 * self.n_kv_heads)
                per_period += self.n_heads * self.head_dim * D
            else:
                mc = self.mamba_cfg()
                per_period += D * (2 * mc.d_inner + 2 * mc.n_groups * mc.d_state + mc.n_heads)
                per_period += mc.d_inner * D + mc.conv_kernel * mc.conv_dim
            if ffn == "dense":
                per_period += D * F * (3 if self.mlp_gated else 2)
            elif ffn == "moe":
                per_period += self.top_k * D * F * (3 if self.mlp_gated else 2)
                per_period += D * self.n_experts
        return total + per_period * self.n_groups


def embed_tokens(p_embed: Params, tokens: jax.Array, cfg, ctx) -> jax.Array:
    """Vocab-parallel embedding lookup (Megatron-style).

    The table is sharded (V -> ep_axis, D replicated); each shard gathers its
    own vocab range with a mask and the results psum over the EP axis. XLA's
    generic sharded-gather falls back to full rematerialization ("Involuntary
    full rematerialization" — refuted hypothesis H-embed, EXPERIMENTS §Perf),
    so the pattern is expressed explicitly with shard_map.
    """
    if ctx is None or ctx.mesh is None:
        return embed(p_embed, tokens, cfg.compute_dtype)
    from jax.sharding import PartitionSpec as P

    bt = ctx.pick_batch_axes(tokens.shape[0])

    def body(tbl, tok):
        vloc = tbl.shape[0]
        lo = jax.lax.axis_index(ctx.ep_axis) * vloc
        rel = tok - lo
        ok = (rel >= 0) & (rel < vloc)
        out = jnp.where(
            ok[..., None],
            tbl.astype(cfg.compute_dtype)[jnp.clip(rel, 0, vloc - 1)],
            jnp.zeros((), cfg.compute_dtype),
        )
        return jax.lax.psum(out, ctx.ep_axis)

    return jax.shard_map(
        body,
        mesh=ctx.mesh,
        in_specs=(P(ctx.ep_axis, None), P(bt)),
        out_specs=P(bt),
        check_vma=False,
    )(p_embed["table"], tokens)


# ------------------------------------------------------------------ init ---


def _block_init(key, cfg: ModelConfig, kind: str, ffn: Optional[str], ep_shards: int) -> Params:
    ks = jax.random.split(key, 4)
    dt = cfg.param_dtype
    p: Params = {"norm1": rmsnorm_init(cfg.d_model, dt)}
    if kind.startswith("attn"):
        p["attn"] = attn_init(ks[0], cfg.attn_cfg(kind), dt)
    else:
        p["mamba"] = mamba_init(ks[0], cfg.mamba_cfg(), dt)
    if ffn is not None:
        p["norm2"] = rmsnorm_init(cfg.d_model, dt)
        if ffn == "dense":
            p["ffn"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, dt, gated=cfg.mlp_gated)
        else:
            p["moe"] = moe_init(ks[1], cfg.moe_cfg(), dt, ep_shards=ep_shards)
    return p


def padded_vocab(cfg: ModelConfig, ep_shards: int) -> int:
    """Vocab rows padded to the EP-shard multiple (vocab-parallel table)."""
    return math.ceil(cfg.vocab_size / ep_shards) * ep_shards


def model_init(key, cfg: ModelConfig, *, ep_shards: int = 1) -> Params:
    """Init full parameter pytree; block params stacked over the group axis."""
    k_embed, k_blocks, k_final = jax.random.split(key, 3)

    def one_group(k):
        ks = jax.random.split(k, len(cfg.pattern))
        return {
            f"pos{i}": _block_init(ks[i], cfg, kind, ffn, ep_shards)
            for i, (kind, ffn) in enumerate(zip(cfg.pattern, cfg.ffn_pattern))
        }

    group_keys = jax.random.split(k_blocks, cfg.n_groups)
    blocks = jax.vmap(one_group)(group_keys)  # leading axis = groups
    return {
        "embed": embed_init(
            k_embed, padded_vocab(cfg, ep_shards), cfg.d_model, cfg.param_dtype
        ),
        "blocks": blocks,
        "final_norm": rmsnorm_init(cfg.d_model, cfg.param_dtype),
    }


# --------------------------------------------------------------- forward ---


@dataclass(frozen=True)
class ShardCtx:
    """How the model parallelizes. mesh=None -> single-device (smoke tests)."""
    mesh: Any = None
    axes: Tuple[str, ...] = ()      # all mesh axis names, batch shards over them
    ep_axis: str = "model"

    @property
    def ep_shards(self) -> int:
        return 1 if self.mesh is None else self.mesh.shape[self.ep_axis]

    @property
    def batch_axes(self) -> Tuple[str, ...]:
        return tuple(a for a in self.axes if a != self.ep_axis)

    def pick_batch_axes(self, n: int) -> Tuple[str, ...]:
        """Largest prefix of batch axes whose sizes divide ``n`` (tiny decode
        batches can't use every axis)."""
        axes, rem = [], n
        for a in self.batch_axes:
            sz = self.mesh.shape[a]
            if rem % sz == 0:
                axes.append(a)
                rem //= sz
        return tuple(axes)

    def constrain_batch(self, x: jax.Array) -> jax.Array:
        """Pin dim0 of an activation to the batch axes (scan-carry anchor)."""
        if self.mesh is None:
            return x
        from jax.sharding import NamedSharding, PartitionSpec as P

        spec = P(self.batch_axes, *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    def constrain_spec(self, x: jax.Array, *axes, allow_uneven: bool = False) -> jax.Array:
        """Pin an activation: entries are "batch", a mesh axis name, or None.

        Non-dividing named dims are dropped unless ``allow_uneven`` (SPMD
        handles padded tilings — needed for 28 heads on a 16-way model axis).
        """
        if self.mesh is None:
            return x
        from jax.sharding import NamedSharding, PartitionSpec as P

        spec = []
        for dim, a in enumerate(axes):
            if a == "batch":
                a = self.batch_axes
            if isinstance(a, str):
                if x.shape[dim] % self.mesh.shape[a] and not allow_uneven:
                    a = None
            spec.append(a)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, P(*spec)))


def _apply_ffn(
    p: Params,
    cfg: ModelConfig,
    x: jax.Array,
    ctx: ShardCtx,
    stats: dict,
    *,
    decode=False,
    moe_capacity: Optional[int] = None,
    moe_stats: bool = False,
):
    """``moe_capacity`` overrides the per-(sender, expert) token capacity
    (static — the train loop's capacity controller threads the learned value
    through here, so a bump recompiles once).  ``moe_stats=True`` adds
    ``moe_dropped``/``moe_peak`` to the stats dict — the exchange-telemetry
    signal the between-step learner and AnomalyMonitor read."""
    h = rmsnorm(p["norm2"], x)
    if "ffn" in p:
        return x + mlp(p["ffn"], h), stats
    B, S, D = h.shape
    if ctx.mesh is not None and not decode:
        # sequence-parallel hand-off: (B->batch, S->model) makes the (B*S, D)
        # token flatten a local view of the full-mesh token sharding the MoE
        # shard_map wants; without it SPMD "involuntarily rematerializes" the
        # residual stream (8 GiB/device f32 on jamba — hypothesis H-sp1)
        h = ctx.constrain_spec(h, "batch", ctx.ep_axis, None)
    flat = h.reshape(B * S, D)
    mcfg = cfg.moe_cfg()
    dropped = peak = None
    if ctx.mesh is None:
        if moe_stats:
            y, aux, dropped, _, peak, overflow = moe_apply_ep_replicated(
                p["moe"], mcfg, flat, capacity=moe_capacity, with_stats=True
            )
        else:
            y, aux, overflow = moe_apply_ep_replicated(
                p["moe"], mcfg, flat, capacity=moe_capacity
            )
    elif decode:
        # decode: tokens replicated over EP axis, psum-combined (moe.py doc).
        # Tiny decode batches may not divide the data axes (long_500k B=1):
        # shard tokens only over axes whose size divides the token count.
        from jax.sharding import PartitionSpec as P

        token_axes = []
        rem = flat.shape[0]
        for a in ctx.axes:
            if a == ctx.ep_axis:
                continue
            sz = ctx.mesh.shape[a]
            if rem % sz == 0:
                token_axes.append(a)
                rem //= sz
        token_axes = tuple(token_axes)
        (p_spec, _), _ = moe_shard_specs(p["moe"], mesh_axes=ctx.axes, ep_axis=ctx.ep_axis)

        def body(mp, xt):
            return moe_apply_ep_replicated(mp, mcfg, xt, ctx.ep_axis, ctx.axes)

        y, aux, overflow = jax.shard_map(
            body,
            mesh=ctx.mesh,
            in_specs=(p_spec, P(token_axes)),
            out_specs=(P(token_axes), P(), P()),
            check_vma=False,
        )(p["moe"], flat)
    else:
        # train/prefill: the paper's model-D all_to_all dispatch
        (p_spec, x_spec), out_specs = moe_shard_specs(
            p["moe"], mesh_axes=ctx.axes, ep_axis=ctx.ep_axis, with_stats=moe_stats
        )

        def body(mp, xt):
            res = moe_apply_local(
                mp, mcfg, xt, ctx.ep_axis, ctx.axes,
                capacity=moe_capacity, with_stats=moe_stats,
            )
            if not moe_stats:
                return res
            out, aux, dropped, counts, peak, overflow = res
            rest = tuple(a for a in ctx.axes if a != ctx.ep_axis)
            if rest:  # stats are EP-group-global; fold in the other axes
                dropped = jax.lax.psum(dropped, rest)
                counts = jax.lax.psum(counts, rest)
                peak = jax.lax.pmax(peak, rest)
            return out, aux, dropped, counts, peak, overflow

        res = jax.shard_map(
            body,
            mesh=ctx.mesh,
            in_specs=(p_spec, x_spec),
            out_specs=out_specs,
            check_vma=False,
        )(p["moe"], flat)
        if moe_stats:
            y, aux, dropped, _, peak, overflow = res
        else:
            y, aux, overflow = res
    stats = dict(stats)
    stats["moe_aux"] = stats.get("moe_aux", 0.0) + aux
    stats["moe_overflow"] = jnp.logical_or(
        stats.get("moe_overflow", jnp.asarray(False)), overflow
    )
    if moe_stats:
        # layer totals: tokens lost this step sum over layers, the hottest
        # per-(sender, expert) count maxes — what the capacity learner reads
        stats["moe_dropped"] = stats.get("moe_dropped", 0) + dropped
        stats["moe_peak"] = jnp.maximum(stats.get("moe_peak", 0), peak)
    y = y.reshape(B, S, D)
    if ctx.mesh is not None and not decode:
        y = ctx.constrain_spec(y, "batch", ctx.ep_axis, None)
    return x + y, stats


def _apply_block(
    p: Params, cfg: ModelConfig, kind: str, ffn, x, ctx, stats,
    *, moe_capacity: Optional[int] = None, moe_stats: bool = False,
):
    h = rmsnorm(p["norm1"], x)
    pin = ctx.constrain_spec if ctx.mesh is not None else None
    if kind.startswith("attn"):
        # head pinning is a fix for the non-divisible-heads pathology only;
        # where H % TP == 0 XLA already shards heads and pins add reshards
        # (H-gqa refinement, EXPERIMENTS §Perf iteration 3)
        attn_pin = pin if (pin and cfg.n_heads % ctx.mesh.shape[ctx.ep_axis]) else None
        x = x + attention_train(p["attn"], cfg.attn_cfg(kind), h, constrain=attn_pin)
    else:
        x = x + mamba_train(p["mamba"], cfg.mamba_cfg(), h, constrain=pin)
    if ffn is not None:
        x, stats = _apply_ffn(
            p, cfg, x, ctx, stats, moe_capacity=moe_capacity, moe_stats=moe_stats
        )
    return x, stats


def forward(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,
    *,
    ctx: ShardCtx = ShardCtx(),
    frontend_embeds: Optional[jax.Array] = None,
    remat: bool = True,
) -> Tuple[jax.Array, dict]:
    """tokens (B,S) -> (logits (B,S,V) fp32, stats). Full-sequence pass."""
    x = embed_tokens(params["embed"], tokens, cfg, ctx)
    if frontend_embeds is not None:
        F = frontend_embeds.shape[1]
        x = jnp.concatenate([frontend_embeds.astype(x.dtype), x[:, F:]], axis=1)

    aux0 = jnp.zeros((), jnp.float32)
    ovf0 = jnp.asarray(False)

    def group_body(carry, gp):
        x, aux, ovf = carry
        x = ctx.constrain_batch(x)  # anchor the scan carry's batch sharding
        stats = {"moe_aux": aux, "moe_overflow": ovf}
        for i, (kind, ffn) in enumerate(zip(cfg.pattern, cfg.ffn_pattern)):
            x, stats = _apply_block(gp[f"pos{i}"], cfg, kind, ffn, x, ctx, stats)
        return (x, stats["moe_aux"], stats["moe_overflow"]), None

    body = group_body
    if remat:
        policy = (
            jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
            if cfg.remat_policy == "dots"
            else None
        )
        body = jax.checkpoint(group_body, policy=policy)
    (x, aux, ovf), _ = jax.lax.scan(body, (x, aux0, ovf0), params["blocks"])
    x = rmsnorm(params["final_norm"], x)
    logits = unembed(params["embed"], x, cfg.vocab_size)
    return logits, {"moe_aux": aux / max(cfg.n_layers, 1), "moe_overflow": ovf}


# ---------------------------------------------------------------- decode ---


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Per-group stacked caches (scan-compatible)."""

    def one(kind: str):
        if kind.startswith("attn"):
            c = init_kv_cache(cfg.attn_cfg(kind), batch, max_len, cfg.compute_dtype)
        else:
            c = init_mamba_cache(cfg.mamba_cfg(), batch, cfg.compute_dtype)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_groups,) + a.shape), c
        )

    return {f"pos{i}": one(kind) for i, kind in enumerate(cfg.pattern)}


def decode_step(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,     # (B, 1) next-token ids
    cache,
    *,
    ctx: ShardCtx = ShardCtx(),
):
    """One decode step through the whole stack. Returns (logits, new_cache)."""
    x = embed_tokens(params["embed"], tokens, cfg, ctx)

    def group_body(x, inputs):
        gp, gcache = inputs
        new_gcache = {}
        for i, (kind, ffn) in enumerate(zip(cfg.pattern, cfg.ffn_pattern)):
            p = gp[f"pos{i}"]
            h = rmsnorm(p["norm1"], x)
            if kind.startswith("attn"):
                out, nc = attention_decode(p["attn"], cfg.attn_cfg(kind), h, gcache[f"pos{i}"])
            else:
                out, nc = mamba_decode(p["mamba"], cfg.mamba_cfg(), h, gcache[f"pos{i}"])
            x = x + out
            new_gcache[f"pos{i}"] = nc
            if ffn is not None:
                x, _ = _apply_ffn(p, cfg, x, ctx, {}, decode=True)
        return x, new_gcache

    x, new_cache = jax.lax.scan(group_body, x, (params["blocks"], cache))
    x = rmsnorm(params["final_norm"], x)
    logits = unembed(params["embed"], x, cfg.vocab_size)
    return logits, new_cache
