"""GQA attention: training (chunked flash-style), prefill, and decode paths.

Variants required by the assigned archs: grouped KV (all), qk-norm (qwen3,
gemma3), QKV bias (qwen2), sliding-window local attention (gemma3 5:1 cadence),
per-kind RoPE theta. Long sequences never materialize the (S, S) score matrix:
training/prefill attention scans over KV chunks with an online-softmax
accumulator (FlashAttention recurrence, expressed in jnp — the TPU kernel
equivalent is fused by XLA; DESIGN.md notes this as a future Pallas hot-spot).
Local (sliding-window) layers use blocked local attention: each query block
attends to its own and the previous key block only — O(S·2w) not O(S²).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from .layers import Params, apply_rope, linear, linear_init, rmsnorm, rmsnorm_init, rope_angles


class AttnConfig(NamedTuple):
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0        # 0 = full/global attention
    kv_chunk: int = 1024           # flash scan chunk (global layers)


def attn_init(key, cfg: AttnConfig, dtype) -> Params:
    ks = jax.random.split(key, 4)
    H, Hk, hd, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    p = {
        "wq": linear_init(ks[0], D, H * hd, dtype, bias=cfg.qkv_bias),
        "wk": linear_init(ks[1], D, Hk * hd, dtype, bias=cfg.qkv_bias),
        "wv": linear_init(ks[2], D, Hk * hd, dtype, bias=cfg.qkv_bias),
        "wo": linear_init(ks[3], H * hd, D, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dtype)
        p["k_norm"] = rmsnorm_init(hd, dtype)
    return p


def _project_qkv(p: Params, cfg: AttnConfig, x: jax.Array, positions: jax.Array):
    B, S, _ = x.shape
    H, Hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = linear(p["wq"], x).reshape(B, S, H, hd)
    k = linear(p["wk"], x).reshape(B, S, Hk, hd)
    v = linear(p["wv"], x).reshape(B, S, Hk, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    cos, sin = rope_angles(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return q, k, v


def _flash_causal(q, k, v, cfg: AttnConfig, constrain=None):
    """Chunked causal attention with online softmax. q (B,S,H,hd); k,v (B,S,Hk,hd).

    Fused-head formulation: KV heads are repeated to the full H inside the
    chunk loop so every einsum parallelizes over the (possibly unevenly
    sharded) head axis. The grouped (Hk, G) form makes SPMD shard the hd
    *contraction* dim when Hk < TP and all-reduce the whole score tensor —
    1.5 TiB/step on qwen2 prefill_32k (confirmed hypothesis H-gqa,
    EXPERIMENTS §Perf iteration 2).
    """
    B, S, H, hd = q.shape
    Hk = k.shape[2]
    G = H // Hk
    C = min(cfg.kv_chunk, S)
    while S % C:  # largest divisor of S not exceeding kv_chunk
        C -= 1
    n_chunks = S // C
    scale = hd ** -0.5

    fused = constrain is not None  # pathological H % TP != 0 case
    if fused:
        qh = q * scale                          # (B, S, H, hd)
    else:
        qh = (q * scale).reshape(B, S, Hk, G, hd)
    kc = k.reshape(B, n_chunks, C, Hk, hd)
    vc = v.reshape(B, n_chunks, C, Hk, hd)
    q_pos = jnp.arange(S)

    def body(carry, inputs):
        m, l, acc = carry
        ci, k_blk, v_blk = inputs
        k_pos = ci * C + jnp.arange(C)
        mask = q_pos[:, None] >= k_pos[None, :]  # causal
        if cfg.sliding_window:
            mask &= q_pos[:, None] - k_pos[None, :] < cfg.sliding_window
        if fused:
            k_rep = jnp.repeat(k_blk, G, axis=2)  # (B, C, H, hd) — local copy
            v_rep = jnp.repeat(v_blk, G, axis=2)
            k_rep = constrain(k_rep, "batch", None, "model", None, allow_uneven=True)
            v_rep = constrain(v_rep, "batch", None, "model", None, allow_uneven=True)
            # scores: (B, S, H, C) fp32, head-sharded (uneven tiling)
            s = jnp.einsum("bshd,bchd->bshc", qh, k_rep,
                           preferred_element_type=jnp.float32)
            mb = mask[None, :, None, :]
        else:
            # grouped scores: (B, S, Hk, G, C) — XLA shards Hk x G cleanly
            s = jnp.einsum("bsxgd,bcxd->bsxgc", qh, k_blk,
                           preferred_element_type=jnp.float32)
            mb = mask[None, :, None, None, :]
        s = jnp.where(mb, s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # guard fully-masked rows (m_new == -inf) -> exp(0)=1 but l stays 0
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mb, p, 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l = l * alpha + p.sum(axis=-1)
        if fused:
            pv = jnp.einsum("bshc,bchd->bshd", p.astype(v_blk.dtype), v_rep,
                            preferred_element_type=jnp.float32)
        else:
            pv = jnp.einsum("bsxgc,bcxd->bsxgd", p.astype(v_blk.dtype), v_blk,
                            preferred_element_type=jnp.float32)
        acc = acc * alpha[..., None] + pv
        return (m_new, l, acc), None

    # derive carries from qh so SPMD batch sharding propagates into the scan
    # (zeros/full constants are shardless -> the carry would unify to
    # replicated and all-gather the batch each chunk; see EXPERIMENTS H-shard)
    a0 = (qh * 0).astype(jnp.float32)
    m0 = a0[..., 0] - jnp.inf
    l0 = a0[..., 0]
    (m, l, acc), _ = jax.lax.scan(
        body,
        (m0, l0, a0),
        (jnp.arange(n_chunks), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0)),
    )
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    return out.reshape(B, S, H, hd).astype(q.dtype)


def _blocked_local(q, k, v, cfg: AttnConfig):
    """Sliding-window attention via (current, previous) key blocks — O(S·2w)."""
    B, S, H, hd = q.shape
    Hk = k.shape[2]
    G = H // Hk
    w = cfg.sliding_window
    S0 = S
    if S % w:  # pad to a block multiple; causal mask keeps pads invisible
        pad = w - S % w
        q, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0))) for t in (q, k, v))
        S = S + pad
    nb = S // w
    scale = hd ** -0.5

    # fused-head form: repeat KV so the (possibly uneven) head dim carries TP
    qb = (q * scale).reshape(B, nb, w, H, hd)
    kb = jnp.repeat(k, G, axis=2).reshape(B, nb, w, H, hd)
    vb = jnp.repeat(v, G, axis=2).reshape(B, nb, w, H, hd)
    # previous block (block 0's "previous" is zeros, fully masked)
    k_prev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    v_prev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    k2 = jnp.concatenate([k_prev, kb], axis=2)  # (B, nb, 2w, H, hd)
    v2 = jnp.concatenate([v_prev, vb], axis=2)

    s = jnp.einsum("bnqhd,bnkhd->bnhqk", qb, k2, preferred_element_type=jnp.float32)
    q_pos = jnp.arange(w)[:, None]
    k_pos = jnp.arange(2 * w)[None, :] - w  # relative to block start
    rel = q_pos - k_pos
    mask = (rel >= 0) & (rel < w)
    blk0_mask = k_pos >= 0  # block 0 has no previous block
    full_mask = jnp.where(
        (jnp.arange(nb) == 0)[:, None, None], mask & blk0_mask, mask
    )  # (nb, w, 2w)
    s = jnp.where(full_mask[None, :, None, :, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bnhqk,bnkhd->bnqhd", p.astype(v2.dtype), v2)
    return out.reshape(B, S, H, hd)[:, :S0].astype(q.dtype)


def _pin_heads(q, k, v, constrain):
    """Anchor (B,S,H,hd) activations: batch on dim0, heads on the model axis
    (uneven tiling allowed). Without this SPMD may shard the hd *contraction*
    dim instead and all-reduce whole score tensors (H-gqa, EXPERIMENTS §Perf)."""
    if constrain is None:
        return q, k, v
    q = constrain(q, "batch", None, "model", None, allow_uneven=True)
    k = constrain(k, "batch", None, "model", None, allow_uneven=True)
    v = constrain(v, "batch", None, "model", None, allow_uneven=True)
    return q, k, v


def attention_train(p: Params, cfg: AttnConfig, x: jax.Array, constrain=None) -> jax.Array:
    """Causal self-attention over the full sequence (training / prefill)."""
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q, k, v = _project_qkv(p, cfg, x, positions)
    q, k, v = _pin_heads(q, k, v, constrain)
    if cfg.sliding_window and S > cfg.sliding_window:
        out = _blocked_local(q, k, v, cfg)
    else:
        out = _flash_causal(q, k, v, cfg, constrain=constrain)
    return linear(p["wo"], out.reshape(B, S, -1))


class KVCache(NamedTuple):
    k: jax.Array          # (B, S_max, Hk, hd) — ring buffer for local layers
    v: jax.Array
    length: jax.Array     # scalar int32: tokens written so far


def init_kv_cache(cfg: AttnConfig, batch: int, max_len: int, dtype) -> KVCache:
    size = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    shape = (batch, size, cfg.n_kv_heads, cfg.head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype), jnp.zeros((), jnp.int32))


def attention_decode(p: Params, cfg: AttnConfig, x: jax.Array, cache: KVCache):
    """One-token decode step. x (B, 1, D). Returns (out, new_cache)."""
    B, _, _ = x.shape
    pos = cache.length
    positions = jnp.broadcast_to(pos, (B, 1))
    q, k_new, v_new = _project_qkv(p, cfg, x, positions)
    size = cache.k.shape[1]
    slot = (pos % size) if cfg.sliding_window else pos
    k = jax.lax.dynamic_update_slice(cache.k, k_new.astype(cache.k.dtype), (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new.astype(cache.v.dtype), (0, slot, 0, 0))

    H, Hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = H // Hk
    qh = (q * hd ** -0.5).reshape(B, 1, Hk, G, hd)
    s = jnp.einsum("bsxgd,btxd->bxgst", qh, k, preferred_element_type=jnp.float32)
    t = jnp.arange(size)
    if cfg.sliding_window:
        age = (slot - t) % size  # age of each ring slot
        valid = (age < jnp.minimum(pos + 1, size))
    else:
        valid = t <= pos
    s = jnp.where(valid[None, None, None, None, :], s, -jnp.inf)
    prob = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bxgst,btxd->bsxgd", prob.astype(v.dtype), v)
    out = linear(p["wo"], out.reshape(B, 1, H * hd))
    return out, KVCache(k, v, pos + 1)
