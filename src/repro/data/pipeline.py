"""Synthetic data pipeline: deterministic, checkpointable, sort-integrated.

* ``SyntheticLM`` — deterministic PRNG token stream (zipf-ish marginals so the
  loss has structure to learn); state = (seed, step) -> restart is bit-exact
  after checkpoint restore (fault-tolerance requirement).
* ``length_bucketed_batches`` — documents-of-varying-length batching: sorts
  the document pool by length with the paper's shared-memory hybrid sort
  (model B) so each batch packs near-equal lengths and padding waste drops;
  this is the dense-arch integration point of the paper (DESIGN.md §3).
* host-side prefetch thread keeps the accelerator fed.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.shared_sort import shared_memory_sort


@dataclass
class PipelineState:
    seed: int
    step: int


class SyntheticLM:
    """Deterministic synthetic LM batches: tokens ~ zipf-ish, labels = shift."""

    def __init__(self, vocab: int, batch: int, seq: int, *, seed: int = 0):
        self.vocab, self.batch, self.seq = vocab, batch, seq
        self.state = PipelineState(seed=seed, step=0)

    def checkpoint_state(self) -> dict:
        return {"seed": self.state.seed, "step": self.state.step}

    def restore_state(self, s: dict) -> None:
        self.state = PipelineState(seed=int(s["seed"]), step=int(s["step"]))

    def _batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.state.seed, step))
        # zipf-ish marginal + a periodic structure the model can learn
        z = rng.zipf(1.3, size=(self.batch, self.seq + 1))
        toks = (z % (self.vocab - 1)).astype(np.int32) + 1
        pattern = (np.arange(self.seq + 1) % 7 == 0)
        toks[:, pattern] = 1 + (np.arange(self.batch, dtype=np.int32) % 7)[:, None]
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:].astype(np.int32),
        }

    def __iter__(self) -> Iterator[dict]:
        while True:
            b = self._batch_at(self.state.step)
            self.state.step += 1
            yield b


class Prefetcher:
    """Host-side background prefetch (keeps step time off the data path)."""

    def __init__(self, it: Iterator[dict], depth: int = 2):
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.it = it
        self._stop = threading.Event()
        self.t = threading.Thread(target=self._run, daemon=True)
        self.t.start()

    def _run(self):
        for item in self.it:
            if self._stop.is_set():
                return
            self.q.put(item)

    def __iter__(self):
        return self

    def __next__(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            self.q.get_nowait()
        except queue.Empty:
            pass


def length_bucketed_batches(doc_lengths: np.ndarray, batch: int, *, n_threads: int = 8):
    """Group document ids into batches of near-equal length.

    Sorts (length, id) with the paper's model-B sort; adjacent ids then form
    minimal-padding batches. Returns (batches (n_batches, batch) of doc ids,
    padding_waste_fraction_before, after).
    """
    n = len(doc_lengths)
    if n * (int(np.max(doc_lengths)) + 1) >= 2**31:
        raise ValueError("length*id packing exceeds int32 (enable x64 or shard the pool)")
    lengths = jnp.asarray(doc_lengths, jnp.int32)
    # stable key-value sort: pack (length, id) — lengths fit comfortably
    packed = lengths * n + jnp.arange(n, dtype=jnp.int32)
    packed_sorted = shared_memory_sort(packed, n_threads=n_threads)
    order = np.asarray(packed_sorted % n, np.int64)
    sorted_len = np.asarray(packed_sorted // n, np.int64)

    usable = (n // batch) * batch
    batches = order[:usable].reshape(-1, batch)
    blens = sorted_len[:usable].reshape(-1, batch)

    def waste(arr):
        mx = arr.max(axis=1, keepdims=True)
        return float((mx - arr).sum() / np.maximum((mx * np.ones_like(arr)).sum(), 1))

    unsorted = np.asarray(doc_lengths)[:usable].reshape(-1, batch)
    return batches, waste(unsorted), waste(blens)
